//! The campaign runner: `dpulens campaign <manifest>` expands a declarative
//! manifest into workload × topology × condition permutations and runs every
//! cell through the same parallel machinery as the matrix/fleet sweeps.
//!
//! A manifest is a small TOML-subset file (serde/toml are not vendored
//! offline, so the parser here is hand-rolled and strict):
//!
//! ```toml
//! [campaign]
//! name = "smoke"
//! seed = 42
//! duration_ms = 1200
//! conditions = ["healthy", "NS2"]
//!
//! [[tenant]]
//! name = "interactive"
//! priority = 0
//! share = 0.5
//! ttft_slo_ms = 2.0
//! tpot_slo_ms = 1.0
//!
//! [[workload]]
//! name = "steady"
//! arrival = "poisson:300"
//! prompt = "pareto:1.4:8:96"
//!
//! [[topology]]
//! name = "single"
//! kind = "single"
//! ```
//!
//! Supported value grammars (all colon-separated spec strings):
//!
//! * `arrival`    — `poisson:RATE` | `uniform:RATE` |
//!   `onoff:ON_RATE:OFF_RATE:MEAN_ON_S:MEAN_OFF_S`
//! * `rate_shape` — `constant` | `diurnal:PERIOD_S:MIN_FACTOR` |
//!   `ramp:FROM:TO:RAMP_S` | `flash:AT_S:SURGE:DECAY_S`, composable with
//!   `*` (product), e.g. `diurnal:60:0.5*flash:0.6:4:0.2`
//! * `prompt`/`output` — `fixed:N` | `uniform:LO:HI` |
//!   `lognormal:MU:SIGMA:LO:HI` | `bimodal:SHORT:LONG:P_SHORT` |
//!   `pareto:ALPHA:LO:HI`
//! * `conditions` — `"healthy"` or any catalog id (`NS2`, `PC5`, ...)
//! * topology `kind` — `single` | `fleet` (with `replicas`) | `disagg`
//!
//! Each cell runs the manifest workload *verbatim* (no catalog shaping —
//! the campaign answers "what does MY traffic look like under condition C",
//! not "can the detector fire on its tuned scenario"), injecting at the
//! standard post-calibration instant. The report carries per-cell detection
//! metrics and per-tenant SLO attainment, and its JSON
//! (`dpulens.campaign.v1`) is byte-identical across runs and thread counts:
//! cells are enumerated in manifest order, results come back in input order
//! (`util::par`), detection counts aggregate through a `BTreeMap`, and
//! wall-clock/thread fields stay out of the JSON.

use std::collections::BTreeMap;

use crate::coordinator::experiment::{inject_time, standard_cfg};
use crate::coordinator::fleet::{disagg_base_cfg, fleet_base_cfg};
use crate::coordinator::scenario::{RunResult, ScenarioCfg};
use crate::coordinator::snapshot::{self, ReuseStats};
use crate::dpu::detectors::Condition;
use crate::metrics::TenantLane;
use crate::sim::dist::{Arrival, LengthDist, RateShape};
use crate::sim::{SimDur, SimTime};
use crate::util::json::Json;
use crate::util::par::resolve_threads;
use crate::util::table::Table;
use crate::workload::generator::WorkloadSpec;
use crate::workload::TenantClass;

// ---------------------------------------------------------------------------
// Manifest model
// ---------------------------------------------------------------------------

/// One axis value of the condition dimension: the healthy control or an
/// injected catalog condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellCondition {
    Healthy,
    Injected(Condition),
}

impl CellCondition {
    pub fn id(self) -> &'static str {
        match self {
            CellCondition::Healthy => "healthy",
            CellCondition::Injected(c) => c.id(),
        }
    }
}

/// One `[[workload]]` entry: a named set of overrides on the topology's
/// base [`WorkloadSpec`]. Unset fields keep the topology default.
#[derive(Debug, Clone, Default)]
pub struct WorkloadDef {
    pub name: String,
    pub arrival: Option<Arrival>,
    pub rate_shape: Option<RateShape>,
    pub prompt: Option<LengthDist>,
    pub output: Option<LengthDist>,
    pub sessions: Option<usize>,
    pub skew: Option<f64>,
    pub thin_frac: Option<f64>,
    pub thin_gap_s: Option<f64>,
}

impl WorkloadDef {
    fn apply(&self, wl: &mut WorkloadSpec) {
        if let Some(a) = self.arrival {
            wl.arrival = a;
        }
        if let Some(ref s) = self.rate_shape {
            wl.rate_shape = s.clone();
        }
        if let Some(p) = self.prompt {
            wl.prompt_len = p;
        }
        if let Some(o) = self.output {
            wl.output_len = o;
        }
        if let Some(n) = self.sessions {
            wl.n_sessions = n.max(1);
        }
        if let Some(s) = self.skew {
            wl.session_skew = s;
        }
        if let Some(f) = self.thin_frac {
            wl.thin_session_frac = f;
        }
        if let Some(g) = self.thin_gap_s {
            wl.thin_extra_gap_s = g;
        }
    }
}

/// The topology a cell is simulated on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// The standard single-replica serving scenario.
    Single,
    /// N colocated replicas (the fleet study's base world).
    Fleet { replicas: usize },
    /// The canonical 2-pool phase-disaggregated world (1 prefill + 2 decode).
    Disagg,
}

/// One `[[topology]]` entry.
#[derive(Debug, Clone)]
pub struct TopologyDef {
    pub name: String,
    pub kind: TopologyKind,
}

impl TopologyDef {
    fn base_cfg(&self) -> ScenarioCfg {
        match self.kind {
            TopologyKind::Single => standard_cfg(),
            TopologyKind::Fleet { replicas } => fleet_base_cfg(replicas),
            TopologyKind::Disagg => disagg_base_cfg(),
        }
    }
}

/// A parsed campaign manifest: the cell axes plus the shared run knobs.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub name: String,
    pub seed: u64,
    pub duration: SimDur,
    pub warmup_windows: u64,
    pub calib_windows: u64,
    pub tenants: Vec<TenantClass>,
    pub conditions: Vec<CellCondition>,
    pub workloads: Vec<WorkloadDef>,
    pub topologies: Vec<TopologyDef>,
    /// Worker threads; 0 = one per available core. CLI-set, not manifest.
    pub threads: usize,
    /// Event-calendar backend every cell runs on (programmatic knob — the
    /// equivalence suite pins `Heap` to diff against the bucket default).
    pub calendar: crate::sim::CalendarKind,
    /// Run every cell from scratch instead of forking shared pre-injection
    /// prefixes (`--no-reuse`; equivalence debugging). CLI-set, not manifest.
    pub no_reuse: bool,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            name: "campaign".to_string(),
            seed: 42,
            duration: SimDur::from_ms(1200),
            warmup_windows: 10,
            calib_windows: 40,
            tenants: Vec::new(),
            conditions: Vec::new(),
            workloads: Vec::new(),
            topologies: Vec::new(),
            threads: 0,
            calendar: crate::sim::CalendarKind::Bucket,
            no_reuse: false,
        }
    }
}

// ---------------------------------------------------------------------------
// TOML-subset parser
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum TomlVal {
    Str(String),
    Num(f64),
    Bool(bool),
    StrArr(Vec<String>),
}

impl TomlVal {
    fn kind(&self) -> &'static str {
        match self {
            TomlVal::Str(_) => "string",
            TomlVal::Num(_) => "number",
            TomlVal::Bool(_) => "bool",
            TomlVal::StrArr(_) => "string array",
        }
    }
}

/// One `[header]` or `[[header]]` block and its `key = value` entries.
#[derive(Debug)]
struct Section {
    header: String,
    array: bool,
    line: usize,
    entries: Vec<(String, TomlVal)>,
}

impl Section {
    fn get(&self, key: &str) -> Option<&TomlVal> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    fn str(&self, key: &str) -> Result<Option<&str>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(TomlVal::Str(s)) => Ok(Some(s)),
            Some(v) => Err(format!("[{}] {key}: expected a string, got {}", self.header, v.kind())),
        }
    }

    fn num(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(TomlVal::Num(x)) => Ok(Some(*x)),
            Some(v) => Err(format!("[{}] {key}: expected a number, got {}", self.header, v.kind())),
        }
    }

    fn strs(&self, key: &str) -> Result<Option<&[String]>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(TomlVal::StrArr(v)) => Ok(Some(v)),
            Some(v) => Err(format!(
                "[{}] {key}: expected a string array, got {}",
                self.header,
                v.kind()
            )),
        }
    }

    /// Reject unknown keys — a typo'd knob must fail loudly, not silently
    /// run the default.
    fn check_keys(&self, allowed: &[&str]) -> Result<(), String> {
        for (k, _) in &self.entries {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "[{}] (line {}): unknown key {k:?}; allowed: {}",
                    self.header,
                    self.line,
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

/// Strip a trailing `#` comment, ignoring `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, ln: usize) -> Result<TomlVal, String> {
    if let Some(s) = v.strip_prefix('"') {
        let s = s
            .strip_suffix('"')
            .ok_or_else(|| format!("line {ln}: unterminated string {v:?}"))?;
        return Ok(TomlVal::Str(s.to_string()));
    }
    if v == "true" {
        return Ok(TomlVal::Bool(true));
    }
    if v == "false" {
        return Ok(TomlVal::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("line {ln}: arrays must open and close on one line"))?;
        let mut items = Vec::new();
        for piece in inner.split(',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue; // trailing comma
            }
            let s = piece
                .strip_prefix('"')
                .and_then(|p| p.strip_suffix('"'))
                .ok_or_else(|| format!("line {ln}: array items must be quoted strings"))?;
            items.push(s.to_string());
        }
        return Ok(TomlVal::StrArr(items));
    }
    v.parse::<f64>()
        .map(TomlVal::Num)
        .map_err(|_| format!("line {ln}: unparsable value {v:?}"))
}

fn parse_sections(text: &str) -> Result<Vec<Section>, String> {
    let mut sections: Vec<Section> = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let ln = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            sections.push(Section {
                header: h.trim().to_string(),
                array: true,
                line: ln,
                entries: Vec::new(),
            });
        } else if let Some(h) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            sections.push(Section {
                header: h.trim().to_string(),
                array: false,
                line: ln,
                entries: Vec::new(),
            });
        } else if let Some((k, v)) = line.split_once('=') {
            let section = sections
                .last_mut()
                .ok_or_else(|| format!("line {ln}: key before any [section]"))?;
            section.entries.push((k.trim().to_string(), parse_value(v.trim(), ln)?));
        } else {
            return Err(format!("line {ln}: expected [section] or key = value, got {line:?}"));
        }
    }
    Ok(sections)
}

// ---------------------------------------------------------------------------
// Spec-string grammars
// ---------------------------------------------------------------------------

fn numf(s: &str, what: &str) -> Result<f64, String> {
    s.parse::<f64>().map_err(|_| format!("{what}: bad number {s:?}"))
}

fn parse_arrival(s: &str) -> Result<Arrival, String> {
    let p: Vec<&str> = s.split(':').collect();
    match (p[0], p.len()) {
        ("poisson", 2) => Ok(Arrival::Poisson { rate: numf(p[1], "arrival")? }),
        ("uniform", 2) => Ok(Arrival::Uniform { rate: numf(p[1], "arrival")? }),
        ("onoff", 5) => Ok(Arrival::OnOff {
            on_rate: numf(p[1], "arrival")?,
            off_rate: numf(p[2], "arrival")?,
            mean_on_s: numf(p[3], "arrival")?,
            mean_off_s: numf(p[4], "arrival")?,
        }),
        _ => Err(format!(
            "arrival {s:?}: expected poisson:RATE | uniform:RATE | \
             onoff:ON:OFF:MEAN_ON_S:MEAN_OFF_S"
        )),
    }
}

fn parse_one_shape(s: &str) -> Result<RateShape, String> {
    let p: Vec<&str> = s.split(':').collect();
    match (p[0], p.len()) {
        ("constant", 1) => Ok(RateShape::Constant),
        ("diurnal", 3) => Ok(RateShape::Diurnal {
            period_s: numf(p[1], "rate_shape")?,
            min_factor: numf(p[2], "rate_shape")?,
        }),
        ("ramp", 4) => Ok(RateShape::Ramp {
            from: numf(p[1], "rate_shape")?,
            to: numf(p[2], "rate_shape")?,
            ramp_s: numf(p[3], "rate_shape")?,
        }),
        ("flash", 4) => Ok(RateShape::FlashCrowd {
            at_s: numf(p[1], "rate_shape")?,
            surge: numf(p[2], "rate_shape")?,
            decay_s: numf(p[3], "rate_shape")?,
        }),
        _ => Err(format!(
            "rate_shape {s:?}: expected constant | diurnal:PERIOD_S:MIN | \
             ramp:FROM:TO:RAMP_S | flash:AT_S:SURGE:DECAY_S"
        )),
    }
}

/// `A*B*...` composes shapes multiplicatively (diurnal baseline × flash
/// crowd is the production pattern the paper's NS family stresses).
fn parse_shape(s: &str) -> Result<RateShape, String> {
    let mut shape: Option<RateShape> = None;
    for part in s.split('*') {
        let one = parse_one_shape(part.trim())?;
        shape = Some(match shape {
            None => one,
            Some(a) => RateShape::compose(a, one),
        });
    }
    shape.ok_or_else(|| "rate_shape: empty spec".to_string())
}

fn parse_len(s: &str, what: &str) -> Result<LengthDist, String> {
    let p: Vec<&str> = s.split(':').collect();
    let n = |i: usize| -> Result<usize, String> {
        p[i].parse::<usize>().map_err(|_| format!("{what}: bad length {:?}", p[i]))
    };
    match (p[0], p.len()) {
        ("fixed", 2) => Ok(LengthDist::Fixed(n(1)?)),
        ("uniform", 3) => Ok(LengthDist::Uniform { lo: n(1)?, hi: n(2)? }),
        ("lognormal", 5) => Ok(LengthDist::LogNormal {
            mu: numf(p[1], what)?,
            sigma: numf(p[2], what)?,
            lo: n(3)?,
            hi: n(4)?,
        }),
        ("bimodal", 4) => Ok(LengthDist::Bimodal {
            short: n(1)?,
            long: n(2)?,
            p_short: numf(p[3], what)?,
        }),
        ("pareto", 4) => Ok(LengthDist::Pareto { alpha: numf(p[1], what)?, lo: n(2)?, hi: n(3)? }),
        _ => Err(format!(
            "{what} {s:?}: expected fixed:N | uniform:LO:HI | lognormal:MU:SIGMA:LO:HI | \
             bimodal:SHORT:LONG:P | pareto:ALPHA:LO:HI"
        )),
    }
}

// ---------------------------------------------------------------------------
// Manifest -> CampaignConfig
// ---------------------------------------------------------------------------

fn parse_campaign_section(cc: &mut CampaignConfig, s: &Section) -> Result<(), String> {
    let keys = ["name", "seed", "duration_ms", "warmup_windows", "calib_windows", "conditions"];
    s.check_keys(&keys)?;
    if let Some(n) = s.str("name")? {
        cc.name = n.to_string();
    }
    if let Some(x) = s.num("seed")? {
        cc.seed = x as u64;
    }
    if let Some(x) = s.num("duration_ms")? {
        cc.duration = SimDur::from_ms(x as u64);
    }
    if let Some(x) = s.num("warmup_windows")? {
        cc.warmup_windows = x as u64;
    }
    if let Some(x) = s.num("calib_windows")? {
        cc.calib_windows = x as u64;
    }
    if let Some(ids) = s.strs("conditions")? {
        for id in ids {
            if id.eq_ignore_ascii_case("healthy") {
                cc.conditions.push(CellCondition::Healthy);
            } else {
                let c = Condition::from_id(&id.to_uppercase())
                    .ok_or_else(|| format!("[campaign] conditions: unknown condition {id:?}"))?;
                cc.conditions.push(CellCondition::Injected(c));
            }
        }
    }
    Ok(())
}

fn parse_tenant_section(s: &Section) -> Result<TenantClass, String> {
    s.check_keys(&["name", "priority", "share", "ttft_slo_ms", "tpot_slo_ms"])?;
    let name = s.str("name")?.ok_or("[[tenant]]: missing name")?;
    Ok(TenantClass::new(
        name,
        s.num("priority")?.unwrap_or(0.0) as u8,
        s.num("share")?.unwrap_or(1.0),
        s.num("ttft_slo_ms")?.unwrap_or(f64::INFINITY),
        s.num("tpot_slo_ms")?.unwrap_or(f64::INFINITY),
    ))
}

fn parse_workload_section(s: &Section) -> Result<WorkloadDef, String> {
    s.check_keys(&[
        "name",
        "arrival",
        "rate_shape",
        "prompt",
        "output",
        "sessions",
        "skew",
        "thin_frac",
        "thin_gap_s",
    ])?;
    let name = s.str("name")?.ok_or("[[workload]]: missing name")?;
    Ok(WorkloadDef {
        name: name.to_string(),
        arrival: s.str("arrival")?.map(parse_arrival).transpose()?,
        rate_shape: s.str("rate_shape")?.map(parse_shape).transpose()?,
        prompt: s.str("prompt")?.map(|p| parse_len(p, "prompt")).transpose()?,
        output: s.str("output")?.map(|o| parse_len(o, "output")).transpose()?,
        sessions: s.num("sessions")?.map(|x| x as usize),
        skew: s.num("skew")?,
        thin_frac: s.num("thin_frac")?,
        thin_gap_s: s.num("thin_gap_s")?,
    })
}

fn parse_topology_section(s: &Section) -> Result<TopologyDef, String> {
    s.check_keys(&["name", "kind", "replicas"])?;
    let kind_str = s.str("kind")?.ok_or("[[topology]]: missing kind")?;
    let kind = match kind_str {
        "single" => TopologyKind::Single,
        "fleet" => {
            let replicas = s.num("replicas")?.map(|x| x as usize).unwrap_or(2).max(1);
            TopologyKind::Fleet { replicas }
        }
        "disagg" => TopologyKind::Disagg,
        other => {
            return Err(format!("[[topology]] kind {other:?}: expected single | fleet | disagg"))
        }
    };
    if kind_str != "fleet" && s.get("replicas").is_some() {
        return Err(format!(
            "[[topology]] replicas only applies to kind \"fleet\" (got {kind_str:?})"
        ));
    }
    let name = s.str("name")?.unwrap_or(kind_str).to_string();
    Ok(TopologyDef { name, kind })
}

impl CampaignConfig {
    /// Parse a manifest. Missing sections fall back to a single default
    /// workload/topology/condition, so the smallest valid manifest is an
    /// empty file (one healthy single-topology cell).
    pub fn parse(text: &str) -> Result<CampaignConfig, String> {
        let mut cc = CampaignConfig::default();
        for s in &parse_sections(text)? {
            match (s.header.as_str(), s.array) {
                ("campaign", false) => parse_campaign_section(&mut cc, s)?,
                ("tenant", true) => cc.tenants.push(parse_tenant_section(s)?),
                ("workload", true) => cc.workloads.push(parse_workload_section(s)?),
                ("topology", true) => cc.topologies.push(parse_topology_section(s)?),
                (h, array) => {
                    let brackets = if array { format!("[[{h}]]") } else { format!("[{h}]") };
                    return Err(format!(
                        "line {}: unknown section {brackets}; expected [campaign], \
                         [[tenant]], [[workload]], or [[topology]]",
                        s.line
                    ));
                }
            }
        }
        if cc.workloads.is_empty() {
            cc.workloads.push(WorkloadDef { name: "default".to_string(), ..Default::default() });
        }
        if cc.topologies.is_empty() {
            let single = TopologyDef { name: "single".to_string(), kind: TopologyKind::Single };
            cc.topologies.push(single);
        }
        if cc.conditions.is_empty() {
            cc.conditions.push(CellCondition::Healthy);
        }
        Ok(cc)
    }
}

// ---------------------------------------------------------------------------
// Cells and execution
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Cell {
    workload: String,
    topology: String,
    condition: CellCondition,
    cfg: ScenarioCfg,
}

/// Enumerate cells in deterministic manifest order:
/// workload-major, then topology, then condition.
fn cells(cc: &CampaignConfig) -> Vec<Cell> {
    let mut v = Vec::with_capacity(cc.workloads.len() * cc.topologies.len() * cc.conditions.len());
    for w in &cc.workloads {
        for t in &cc.topologies {
            for &cond in &cc.conditions {
                let mut cfg = t.base_cfg();
                cfg.seed = cc.seed;
                cfg.calendar = cc.calendar;
                cfg.duration = cc.duration;
                cfg.warmup_windows = cc.warmup_windows;
                cfg.calib_windows = cc.calib_windows;
                w.apply(&mut cfg.workload);
                cfg.workload.tenants = cc.tenants.clone();
                cfg.inject = match cond {
                    CellCondition::Healthy => None,
                    CellCondition::Injected(c) => Some((c, inject_time(&cfg))),
                };
                v.push(Cell {
                    workload: w.name.clone(),
                    topology: t.name.clone(),
                    condition: cond,
                    cfg,
                });
            }
        }
    }
    v
}

/// One executed permutation: detection metrics plus per-tenant SLO lanes.
#[derive(Debug, Clone)]
pub struct CampaignCell {
    pub workload: String,
    pub topology: String,
    pub condition: CellCondition,
    /// The injection never landed (duration too short): a hard miss, and
    /// the cell's detection counts are withheld rather than crediting
    /// pre-injection firings.
    pub missed_injection: bool,
    pub detected: bool,
    pub latency_ns: Option<u64>,
    /// Post-injection detection counts (full-run for healthy cells),
    /// sorted by condition.
    pub detections: Vec<(Condition, u64)>,
    pub windows: u64,
    pub requests_generated: usize,
    pub requests_arrived: usize,
    pub requests_tracked: usize,
    pub tenants: Vec<TenantLane>,
}

impl CampaignCell {
    fn injected(&self) -> bool {
        matches!(self.condition, CellCondition::Injected(_))
    }

    fn min_ttft_attainment(&self) -> f64 {
        self.tenants.iter().map(|l| l.ttft_attainment()).fold(1.0, f64::min)
    }

    fn min_tpot_attainment(&self) -> f64 {
        self.tenants.iter().map(|l| l.tpot_attainment()).fold(1.0, f64::min)
    }

    fn to_json(&self) -> Json {
        let mut dets = Json::arr();
        for (c, n) in &self.detections {
            dets.push(Json::obj().set("condition", c.id()).set("count", *n));
        }
        let mut lanes = Json::arr();
        for l in &self.tenants {
            lanes.push(l.to_json());
        }
        Json::obj()
            .set("workload", self.workload.as_str())
            .set("topology", self.topology.as_str())
            .set("condition", self.condition.id())
            .set("injected", self.injected())
            .set("missed_injection", self.missed_injection)
            .set("detected", self.detected)
            .set("latency_ns", self.latency_ns.map(Json::from).unwrap_or(Json::Null))
            .set("detections", dets)
            .set("windows", self.windows)
            .set(
                "requests",
                Json::obj()
                    .set("generated", self.requests_generated)
                    .set("arrived", self.requests_arrived)
                    .set("tracked", self.requests_tracked),
            )
            .set("tenants", lanes)
    }
}

fn score_cell(
    workload: String,
    topology: String,
    condition: CellCondition,
    res: &RunResult,
) -> CampaignCell {
    let injected = match condition {
        CellCondition::Injected(c) => Some(c),
        CellCondition::Healthy => None,
    };
    let missed_injection = injected.is_some() && res.injected_at.is_none();
    let t0 = res.injected_at.unwrap_or(SimTime::ZERO);
    let mut counts: BTreeMap<Condition, u64> = BTreeMap::new();
    if !missed_injection {
        for d in &res.detections {
            if d.at >= t0 {
                *counts.entry(d.condition).or_insert(0) += 1;
            }
        }
    }
    let detected = injected.map(|c| counts.get(&c).copied().unwrap_or(0) > 0).unwrap_or(false);
    let latency_ns = injected.and_then(|c| res.detection_latency(c)).map(|d| d.ns());
    CampaignCell {
        workload,
        topology,
        condition,
        missed_injection,
        detected,
        latency_ns,
        detections: counts.into_iter().collect(),
        windows: res.windows,
        requests_generated: res.requests_generated,
        requests_arrived: res.requests_arrived,
        requests_tracked: res.requests_tracked,
        tenants: res.tenants.clone(),
    }
}

// ---------------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------------

/// The aggregated campaign: every cell in manifest order. JSON is
/// byte-deterministic across runs and thread counts (wall-clock and
/// thread fields are report-only).
#[derive(Debug)]
pub struct CampaignReport {
    pub name: String,
    pub seed: u64,
    pub n_workloads: usize,
    pub n_topologies: usize,
    pub n_conditions: usize,
    pub cells: Vec<CampaignCell>,
    pub threads_used: usize,
    pub elapsed_ms: f64,
    /// Snapshot-and-branch prefix-reuse accounting. Perf metadata like
    /// `elapsed_ms`: excluded from `to_json` so the campaign JSON stays
    /// byte-identical whether or not reuse was enabled.
    pub reuse: ReuseStats,
}

impl CampaignReport {
    pub fn to_json(&self) -> Json {
        let mut cells = Json::arr();
        for c in &self.cells {
            cells.push(c.to_json());
        }
        Json::obj()
            .set("schema", "dpulens.campaign.v1")
            .set("campaign", self.name.as_str())
            .set("seed", self.seed)
            .set("workloads", self.n_workloads)
            .set("topologies", self.n_topologies)
            .set("conditions", self.n_conditions)
            .set("cells", cells)
    }

    pub fn render_tables(&self) -> String {
        let fmt_att = |x: f64| format!("{:.3}", x);
        let mut t = Table::new(&format!("campaign {}", self.name)).header(&[
            "workload",
            "topology",
            "condition",
            "det",
            "lat ms",
            "tracked",
            "ttft att",
            "tpot att",
        ]);
        for c in &self.cells {
            let det = if !c.injected() {
                "-".to_string()
            } else if c.missed_injection {
                "miss".to_string()
            } else if c.detected {
                "yes".to_string()
            } else {
                "no".to_string()
            };
            let lat = c
                .latency_ns
                .map(|l| format!("{:.1}", l as f64 / 1e6))
                .unwrap_or_else(|| "-".to_string());
            t.row(vec![
                c.workload.clone(),
                c.topology.clone(),
                c.condition.id().to_string(),
                det,
                lat,
                c.requests_tracked.to_string(),
                fmt_att(c.min_ttft_attainment()),
                fmt_att(c.min_tpot_attainment()),
            ]);
        }
        let mut s = t.render();
        // Per-tenant SLO lanes, only when the manifest declared classes
        // (the implicit "all" lane would just repeat the cell table).
        if self.cells.iter().any(|c| c.tenants.len() > 1) {
            let mut lt = Table::new("tenant SLO lanes").header(&[
                "workload",
                "topology",
                "condition",
                "tenant",
                "prio",
                "done",
                "rej",
                "ttft att",
                "tpot att",
            ]);
            for c in &self.cells {
                for l in &c.tenants {
                    lt.row(vec![
                        c.workload.clone(),
                        c.topology.clone(),
                        c.condition.id().to_string(),
                        l.name.clone(),
                        l.priority.to_string(),
                        l.completed.to_string(),
                        l.rejected.to_string(),
                        fmt_att(l.ttft_attainment()),
                        fmt_att(l.tpot_attainment()),
                    ]);
                }
            }
            s.push_str(&lt.render());
        }
        s
    }

    pub fn summary_line(&self) -> String {
        let injected = self.cells.iter().filter(|c| c.injected()).count();
        let detected = self.cells.iter().filter(|c| c.injected() && c.detected).count();
        let min_ttft = self.cells.iter().map(|c| c.min_ttft_attainment()).fold(1.0, f64::min);
        let min_tpot = self.cells.iter().map(|c| c.min_tpot_attainment()).fold(1.0, f64::min);
        format!(
            "campaign {}: {} cells ({detected}/{injected} injected detected), \
             min tenant attainment ttft {min_ttft:.3} tpot {min_tpot:.3}",
            self.name,
            self.cells.len()
        )
    }
}

/// Expand the manifest into cells and execute them on the shared scoped
/// worker pool.
pub fn run_campaign(cc: &CampaignConfig) -> CampaignReport {
    let cell_list = cells(cc);
    let threads_used = resolve_threads(cc.threads, cell_list.len());
    let timer = crate::util::perf::PhaseTimer::start();
    // Cells are consumed: the identity columns stay behind for scoring, the
    // configs move into the snapshot runner (no per-cell ScenarioCfg clone).
    let (metas, cfgs): (Vec<(String, String, CellCondition)>, Vec<ScenarioCfg>) = cell_list
        .into_iter()
        .map(|c| ((c.workload, c.topology, c.condition), c.cfg))
        .unzip();
    let (results, reuse) = snapshot::run_all(cfgs, cc.threads, cc.no_reuse);
    let outcomes = metas
        .into_iter()
        .zip(results.iter())
        .map(|((w, t, cond), res)| score_cell(w, t, cond, res))
        .collect();
    let elapsed_ms = timer.total_ms();
    CampaignReport {
        name: cc.name.clone(),
        seed: cc.seed,
        n_workloads: cc.workloads.len(),
        n_topologies: cc.topologies.len(),
        n_conditions: cc.conditions.len(),
        cells: outcomes,
        threads_used,
        elapsed_ms,
        reuse,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"
# exercise every section and grammar
[campaign]
name = "unit"
seed = 7
duration_ms = 1000
warmup_windows = 8
calib_windows = 30
conditions = ["healthy", "NS2"]

[[tenant]]
name = "interactive"
priority = 0
share = 0.5
ttft_slo_ms = 2.0
tpot_slo_ms = 1.0

[[tenant]]
name = "batch"
priority = 1
share = 0.5

[[workload]]
name = "steady"
arrival = "poisson:280"
prompt = "uniform:8:32"
output = "uniform:2:8"

[[workload]]
name = "spiky"
arrival = "onoff:400:50:0.2:0.2"
rate_shape = "diurnal:2:0.6*flash:0.6:3:0.2"  # composed shape
prompt = "pareto:1.4:8:96"
sessions = 32
skew = 1.2

[[topology]]
name = "single"
kind = "single"
"#;

    #[test]
    fn parses_a_full_manifest() {
        let cc = CampaignConfig::parse(MANIFEST).unwrap();
        assert_eq!(cc.name, "unit");
        assert_eq!(cc.seed, 7);
        assert_eq!(cc.duration, SimDur::from_ms(1000));
        assert_eq!(cc.warmup_windows, 8);
        assert_eq!(cc.calib_windows, 30);
        assert_eq!(cc.tenants.len(), 2);
        assert_eq!(cc.tenants[1].name, "batch");
        assert!(cc.tenants[1].ttft_slo_ms.is_infinite());
        assert_eq!(
            cc.conditions,
            vec![CellCondition::Healthy, CellCondition::Injected(Condition::Ns2IngressStarvation)]
        );
        assert_eq!(cc.workloads.len(), 2);
        assert_eq!(cc.workloads[0].arrival, Some(Arrival::Poisson { rate: 280.0 }));
        assert!(matches!(cc.workloads[1].rate_shape, Some(RateShape::Compose(_, _))));
        assert_eq!(
            cc.workloads[1].prompt,
            Some(LengthDist::Pareto { alpha: 1.4, lo: 8, hi: 96 })
        );
        assert_eq!(cc.topologies.len(), 1);
        assert_eq!(cc.topologies[0].kind, TopologyKind::Single);
    }

    #[test]
    fn empty_manifest_yields_one_healthy_cell() {
        let cc = CampaignConfig::parse("").unwrap();
        assert_eq!(cc.workloads.len(), 1);
        assert_eq!(cc.topologies.len(), 1);
        assert_eq!(cc.conditions, vec![CellCondition::Healthy]);
        let v = cells(&cc);
        assert_eq!(v.len(), 1);
        assert!(v[0].cfg.inject.is_none());
    }

    #[test]
    fn cells_expand_in_manifest_order() {
        let cc = CampaignConfig::parse(MANIFEST).unwrap();
        let v = cells(&cc);
        assert_eq!(v.len(), 4); // 2 workloads x 1 topology x 2 conditions
        assert_eq!((v[0].workload.as_str(), v[0].condition.id()), ("steady", "healthy"));
        assert_eq!((v[1].workload.as_str(), v[1].condition.id()), ("steady", "NS2"));
        assert_eq!((v[3].workload.as_str(), v[3].condition.id()), ("spiky", "NS2"));
        // Shared knobs thread into every cell; injection lands after
        // calibration.
        for c in &v {
            assert_eq!(c.cfg.seed, 7);
            assert_eq!(c.cfg.workload.tenants.len(), 2);
            if let Some((_, at)) = c.cfg.inject {
                assert!(at > SimTime((8 + 30) * c.cfg.window.ns()));
            }
        }
        // The spiky workload's overrides landed; the steady one kept the
        // topology sessions default.
        assert_eq!(v[2].cfg.workload.n_sessions, 32);
        assert!(matches!(v[2].cfg.workload.prompt_len, LengthDist::Pareto { .. }));
    }

    #[test]
    fn td_conditions_are_addressable_by_catalog_id() {
        // The TD family rides the same catalog-id grammar as every other
        // condition: a manifest can summon a degraded-telemetry cell
        // without any new manifest syntax.
        let cc = CampaignConfig::parse(
            "[campaign]\nconditions = [\"healthy\", \"TD1\", \"td2\", \"TD3\"]\n",
        )
        .unwrap();
        assert_eq!(
            cc.conditions,
            vec![
                CellCondition::Healthy,
                CellCondition::Injected(Condition::Td1StaleFrozen),
                CellCondition::Injected(Condition::Td2LossyDrop),
                CellCondition::Injected(Condition::Td3LaggingDelivery),
            ]
        );
        let v = cells(&cc);
        assert_eq!(v.len(), 4);
        assert!(matches!(v[1].cfg.inject, Some((Condition::Td1StaleFrozen, _))));
    }

    #[test]
    fn parser_rejects_typos_and_garbage() {
        for (bad, needle) in [
            ("[campaign]\nnmae = \"x\"", "unknown key"),
            ("[campaign]\nconditions = [\"XX99\"]", "unknown condition"),
            ("[[workload]]\nname = \"w\"\narrival = \"poisson\"", "arrival"),
            ("[[workload]]\narrival = \"poisson:1\"", "missing name"),
            ("[[topology]]\nname = \"t\"", "missing kind"),
            ("[[topology]]\nkind = \"mesh\"", "expected single | fleet | disagg"),
            ("[[topology]]\nkind = \"single\"\nreplicas = 4", "only applies to kind"),
            ("[workload]\nname = \"w\"", "unknown section"),
            ("stray", "expected [section]"),
            ("[campaign]\nseed = \"many\"", "expected a number"),
            ("[campaign]\nname = \"unterminated", "unterminated string"),
        ] {
            let err = CampaignConfig::parse(bad).unwrap_err();
            assert!(err.contains(needle), "manifest {bad:?}: error {err:?} lacks {needle:?}");
        }
    }

    #[test]
    fn comments_and_quotes_interact_correctly() {
        let cc =
            CampaignConfig::parse("[campaign]\nname = \"a # not a comment\" # real comment\n")
                .unwrap();
        assert_eq!(cc.name, "a # not a comment");
    }

    #[test]
    fn fleet_and_disagg_topologies_build() {
        let cc = CampaignConfig::parse(
            "[[topology]]\nkind = \"fleet\"\nreplicas = 3\n[[topology]]\nkind = \"disagg\"\n",
        )
        .unwrap();
        assert_eq!(cc.topologies[0].kind, TopologyKind::Fleet { replicas: 3 });
        assert_eq!(cc.topologies[0].name, "fleet"); // name defaults to kind
        let v = cells(&cc);
        assert_eq!(v[0].cfg.cluster.n_nodes, 6); // 2 nodes per fleet replica
        assert_eq!(v[1].cfg.cluster.n_nodes, 6); // disagg world is 6 nodes
        assert!(v[1].cfg.engine.shapes.is_some());
    }
}
