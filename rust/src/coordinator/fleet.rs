//! Fleet sweep: the replicas × routing-policy serving study plus the DP1-DP3
//! data-parallel condition experiments (inject → detect → mitigate) — the
//! engine behind `dpulens fleet`.
//!
//! A fleet world uses single-node pipeline stages so the default 4-GPU nodes
//! yield `2 × replicas` nodes and `replicas` data-parallel lanes. The sweep
//! runs, fanned out over `util::par` worker threads:
//!
//! * one healthy cell per routing policy (per-replica skew columns), and
//! * per DP condition, a healthy / injected / mitigated triple on the
//!   skew-prone affinity-hash baseline — all three on the same shaped
//!   config, so recovery is measured against a like-for-like reference.
//!
//! Aggregation order is fixed by the cell list, so the JSON form is
//! byte-identical across runs and `--threads` values.

use crate::coordinator::experiment::{inject_time, standard_cfg};
use crate::coordinator::scenario::{Scenario, ScenarioCfg};
use crate::dpu::detectors::{Condition, DP_CONDITIONS};
use crate::engine::router::ALL_POLICIES;
use crate::engine::RoutePolicy;
use crate::sim::{SimDur, SimTime};
use crate::util::json::Json;
use crate::util::par::{parallel_map, resolve_threads};
use crate::util::table::{fmt_ns, Table};

/// Extra measurement time DP cells get past the standard duration, so the
/// post-mitigation phase is long enough for throughput to visibly recover.
const DP_EXTRA_MS: u64 = 1600;

/// Fleet-sweep configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Base scenario every cell derives from (already fleet-shaped).
    pub base: ScenarioCfg,
    pub replicas: usize,
    /// Routing policies swept for the healthy study.
    pub policies: Vec<RoutePolicy>,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
}

impl FleetConfig {
    pub fn new(replicas: usize) -> Self {
        FleetConfig {
            base: fleet_base_cfg(replicas),
            replicas,
            policies: ALL_POLICIES.to_vec(),
            threads: 0,
        }
    }
}

/// Base scenario for an `n`-replica fleet: single-node pipeline stages
/// (2 nodes per replica on the default spec), arrival scaled to the fleet,
/// and the victim replica set to the last (non-zero) lane.
pub fn fleet_base_cfg(replicas: usize) -> ScenarioCfg {
    assert!(replicas >= 1);
    let mut cfg = standard_cfg();
    cfg.cluster.n_nodes = 2 * replicas;
    cfg.cluster.pp_degree = 2;
    cfg.engine.nodes_per_stage = 1;
    cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 250.0 * replicas as f64 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 32 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    cfg.victim_replica = replicas.saturating_sub(1);
    cfg
}

/// One cell of the fleet sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FleetCell {
    Policy(RoutePolicy),
    /// The DP condition's shaped config WITHOUT the injection — the
    /// like-for-like recovery baseline.
    DpHealthy(Condition),
    DpInjected(Condition),
    DpMitigated(Condition),
}

/// The shared shaping every cell of one DP condition's triple (healthy /
/// injected / mitigated) runs on, so their throughputs are comparable.
fn dp_shaped(fc: &FleetConfig, c: Condition) -> ScenarioCfg {
    let mut cfg = fc.base.clone();
    // DP conditions are studied on the skew-prone affinity baseline.
    cfg.engine.route_policy = RoutePolicy::FlowHash;
    cfg.duration = cfg.duration + SimDur::from_ms(DP_EXTRA_MS);
    match c {
        // Saturation-sensitive conditions need a compute-dominated cost
        // profile (cf. `shaped_cfg` for EW1): on the fast `small` model a
        // hot or slowed replica never runs out of capacity, so flow
        // concentration / degraded GPUs would not move throughput. The rate
        // scale keeps the hot/slow lane decisively past the 7b compute
        // bound while healthy lanes stay inside it.
        Condition::Dp1RouterFlowSkew => {
            cfg.engine.profile = crate::engine::preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            scale_rate(&mut cfg, 3.0);
        }
        Condition::Dp3StragglerReplica => {
            cfg.engine.profile = crate::engine::preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            scale_rate(&mut cfg, 2.0);
        }
        // DP2's KV leak is capacity-independent: the victim's pool starves
        // outright regardless of the cost profile.
        _ => {}
    }
    cfg
}

fn cell_cfg(fc: &FleetConfig, cell: FleetCell) -> ScenarioCfg {
    match cell {
        FleetCell::Policy(p) => {
            let mut cfg = fc.base.clone();
            cfg.engine.route_policy = p;
            cfg
        }
        FleetCell::DpHealthy(c) => dp_shaped(fc, c),
        FleetCell::DpInjected(c) | FleetCell::DpMitigated(c) => {
            let mut cfg = dp_shaped(fc, c);
            cfg.inject = Some((c, inject_time(&cfg)));
            cfg.mitigate = matches!(cell, FleetCell::DpMitigated(_));
            cfg
        }
    }
}

fn scale_rate(cfg: &mut ScenarioCfg, factor: f64) {
    if let crate::sim::dist::Arrival::Poisson { rate } = &cfg.workload.arrival {
        let scaled = rate * factor;
        cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: scaled };
    }
}

fn cells(fc: &FleetConfig) -> Vec<FleetCell> {
    let mut v: Vec<FleetCell> = fc.policies.iter().map(|&p| FleetCell::Policy(p)).collect();
    for c in DP_CONDITIONS {
        v.push(FleetCell::DpHealthy(c));
        v.push(FleetCell::DpInjected(c));
        v.push(FleetCell::DpMitigated(c));
    }
    v
}

/// Compact per-cell result shipped back from a worker thread.
#[derive(Debug, Clone)]
struct CellOutcome {
    completed: u64,
    rejected: u64,
    tok_per_s: f64,
    req_per_s: f64,
    ttft_p50_ns: f64,
    ttft_p99_ns: f64,
    token_skew: f64,
    max_flow_share: f64,
    replica_tokens: Vec<u64>,
    kv_peak: Vec<f64>,
    detected: bool,
    latency_ns: Option<u64>,
    actions: u64,
    /// Telemetry events the cell's pipeline delivered (perf accounting).
    events: u64,
}

fn run_cell(fc: &FleetConfig, cell: FleetCell) -> CellOutcome {
    let cfg = cell_cfg(fc, cell);
    let res = Scenario::new(cfg).run();
    let injected = match cell {
        FleetCell::DpInjected(c) | FleetCell::DpMitigated(c) => Some(c),
        FleetCell::Policy(_) | FleetCell::DpHealthy(_) => None,
    };
    let t0 = res.injected_at.unwrap_or(SimTime(u64::MAX));
    let detected = injected
        .map(|c| res.detections.iter().any(|d| d.condition == c && d.at >= t0))
        .unwrap_or(false);
    let latency_ns = injected.and_then(|c| res.detection_latency(c)).map(|d| d.ns());
    let total_routed: u64 = res.replica_routed.iter().sum();
    let max_flow_share = if total_routed == 0 {
        0.0
    } else {
        *res.replica_routed.iter().max().unwrap() as f64 / total_routed as f64
    };
    CellOutcome {
        completed: res.metrics.completed,
        rejected: res.metrics.rejected,
        tok_per_s: res.metrics.tok_per_s(),
        req_per_s: res.metrics.req_per_s(),
        ttft_p50_ns: res.metrics.ttft_ns.p50(),
        ttft_p99_ns: res.metrics.ttft_ns.p99(),
        token_skew: res.metrics.replica_token_skew(),
        max_flow_share,
        replica_tokens: res.metrics.per_replica.iter().map(|l| l.tokens_out).collect(),
        kv_peak: res.replica_kv_peak,
        detected,
        latency_ns,
        actions: res.actions.len() as u64,
        events: res.telemetry_published,
    }
}

/// One healthy routing-policy row.
#[derive(Debug, Clone)]
pub struct PolicyRow {
    pub policy: RoutePolicy,
    pub completed: u64,
    pub rejected: u64,
    pub req_per_s: f64,
    pub tok_per_s: f64,
    pub ttft_p50_ns: f64,
    pub ttft_p99_ns: f64,
    /// Max-over-mean token share across replicas (1.0 = balanced).
    pub token_skew: f64,
    /// Largest per-replica share of routed arrivals.
    pub max_flow_share: f64,
    pub replica_tokens: Vec<u64>,
    pub kv_peak: Vec<f64>,
}

/// One DP condition's inject → detect → mitigate row.
#[derive(Debug, Clone)]
pub struct DpRow {
    pub condition: Condition,
    pub detected: bool,
    pub latency_ns: Option<u64>,
    pub healthy_tok_per_s: f64,
    pub injected_tok_per_s: f64,
    pub mitigated_tok_per_s: f64,
    /// Fraction of lost throughput the closed loop recovered, measured
    /// against the same shaped config WITHOUT the injection (clamped to
    /// 0..1.5). For conditions whose injection itself raises demand (DP1's
    /// flash crowd), the baseline reflects pre-surge demand, so the value
    /// saturates high once the mitigated fleet outserves it.
    pub recovery: Option<f64>,
    pub injected_token_skew: f64,
    pub mitigated_token_skew: f64,
    /// Mitigation actions taken in the mitigated run.
    pub actions: u64,
}

/// Everything a fleet sweep produces.
#[derive(Debug)]
pub struct FleetReport {
    pub replicas: usize,
    pub base_seed: u64,
    pub policy_rows: Vec<PolicyRow>,
    pub dp_rows: Vec<DpRow>,
    pub cells_run: usize,
    pub threads_used: usize,
    /// Wall-clock of the parallel cell sweep, ms. Perf metadata: reported
    /// in the human output and `dpulens perf`, excluded from `to_json` so
    /// the fleet JSON stays byte-identical across thread counts.
    pub elapsed_ms: f64,
    /// Telemetry events delivered across all cells' pipelines.
    pub events_total: u64,
}

impl FleetReport {
    /// Pipeline ingest throughput of the whole sweep (events/sec).
    pub fn events_per_sec(&self) -> f64 {
        crate::util::perf::events_per_sec(self.events_total, self.elapsed_ms)
    }
}

/// Execute the fleet sweep in parallel and aggregate in cell order.
/// Wall-clock and events/sec land in the report's perf fields (excluded
/// from the deterministic JSON; see `FleetReport::to_json`).
pub fn run_fleet(fc: &FleetConfig) -> FleetReport {
    let cell_list = cells(fc);
    let threads_used = resolve_threads(fc.threads, cell_list.len());
    let timer = crate::util::perf::PhaseTimer::start();
    let mut outcomes = parallel_map(&cell_list, fc.threads, |&cell| run_cell(fc, cell));
    let elapsed_ms = timer.total_ms();
    let events_total: u64 = outcomes.iter().map(|o| o.events).sum();

    let n_pol = fc.policies.len();
    // The DP triples only need scalar outcomes; the policy rows take the
    // per-replica vectors by move (no re-clone of worker results).
    let dp_outcomes = outcomes.split_off(n_pol);
    let policy_rows: Vec<PolicyRow> = fc
        .policies
        .iter()
        .zip(outcomes)
        .map(|(&policy, o)| PolicyRow {
            policy,
            completed: o.completed,
            rejected: o.rejected,
            req_per_s: o.req_per_s,
            tok_per_s: o.tok_per_s,
            ttft_p50_ns: o.ttft_p50_ns,
            ttft_p99_ns: o.ttft_p99_ns,
            token_skew: o.token_skew,
            max_flow_share: o.max_flow_share,
            replica_tokens: o.replica_tokens,
            kv_peak: o.kv_peak,
        })
        .collect();

    let mut dp_rows = Vec::with_capacity(DP_CONDITIONS.len());
    for (k, c) in DP_CONDITIONS.into_iter().enumerate() {
        // Each condition's triple runs the SAME shaped config, so the
        // healthy cell is a like-for-like recovery baseline.
        let healthy = &dp_outcomes[3 * k];
        let inj = &dp_outcomes[3 * k + 1];
        let mit = &dp_outcomes[3 * k + 2];
        let recovery = if healthy.tok_per_s - inj.tok_per_s < 1e-9 {
            Some(1.0)
        } else {
            Some(
                ((mit.tok_per_s - inj.tok_per_s) / (healthy.tok_per_s - inj.tok_per_s))
                    .clamp(0.0, 1.5),
            )
        };
        dp_rows.push(DpRow {
            condition: c,
            detected: inj.detected,
            latency_ns: inj.latency_ns,
            healthy_tok_per_s: healthy.tok_per_s,
            injected_tok_per_s: inj.tok_per_s,
            mitigated_tok_per_s: mit.tok_per_s,
            recovery,
            injected_token_skew: inj.token_skew,
            mitigated_token_skew: mit.token_skew,
            actions: mit.actions,
        });
    }

    FleetReport {
        replicas: fc.replicas,
        base_seed: fc.base.seed,
        policy_rows,
        dp_rows,
        cells_run: cell_list.len(),
        threads_used,
        elapsed_ms,
        events_total,
    }
}

impl FleetReport {
    /// Paper-style tables: the policy study and the DP condition study.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new(&format!(
            "Fleet study — {} replicas × routing policies (healthy)",
            self.replicas
        ))
        .header(&[
            "policy", "done", "rej", "req/s", "tok/s", "ttft p50", "ttft p99", "tok skew",
            "max share", "kv peak",
        ]);
        for r in &self.policy_rows {
            let kv_peak = r.kv_peak.iter().cloned().fold(0.0_f64, f64::max);
            t.row(vec![
                r.policy.id().to_string(),
                format!("{}", r.completed),
                format!("{}", r.rejected),
                format!("{:.1}", r.req_per_s),
                format!("{:.0}", r.tok_per_s),
                fmt_ns(r.ttft_p50_ns),
                fmt_ns(r.ttft_p99_ns),
                format!("{:.2}", r.token_skew),
                format!("{:.2}", r.max_flow_share),
                format!("{:.2}", kv_peak),
            ]);
        }
        let mut out = t.render();
        let mut d = Table::new("DP condition family — inject, detect, mitigate (affinity baseline)")
            .header(&[
                "id", "detected", "latency", "healthy tok/s", "injected", "mitigated",
                "recovered", "skew inj->mit", "actions",
            ]);
        for r in &self.dp_rows {
            d.row(vec![
                r.condition.id().to_string(),
                if r.detected { "yes".into() } else { "NO".into() },
                r.latency_ns.map(|n| fmt_ns(n as f64)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.healthy_tok_per_s),
                format!("{:.0}", r.injected_tok_per_s),
                format!("{:.0}", r.mitigated_tok_per_s),
                r.recovery.map(|f| format!("{:.0}%", f * 100.0)).unwrap_or_else(|| "-".into()),
                format!("{:.2} -> {:.2}", r.injected_token_skew, r.mitigated_token_skew),
                format!("{}", r.actions),
            ]);
        }
        out.push_str(&d.render());
        out
    }

    /// One-paragraph human summary.
    pub fn summary_line(&self) -> String {
        let best = self
            .policy_rows
            .iter()
            .max_by(|a, b| a.tok_per_s.partial_cmp(&b.tok_per_s).unwrap());
        let detected = self.dp_rows.iter().filter(|r| r.detected).count();
        let mut s = format!(
            "fleet of {} replicas: DP conditions detected {}/{}",
            self.replicas,
            detected,
            self.dp_rows.len()
        );
        if let Some(b) = best {
            s.push_str(&format!(
                "; best healthy policy {} at {:.0} tok/s (token skew {:.2})",
                b.policy.id(),
                b.tok_per_s,
                b.token_skew
            ));
        }
        s
    }

    /// Deterministic JSON: same config + seed ⇒ byte-identical output,
    /// independent of worker-thread count (wallclock/threads excluded).
    pub fn to_json(&self) -> Json {
        let mut policies = Json::arr();
        for r in &self.policy_rows {
            let mut tokens = Json::arr();
            for &t in &r.replica_tokens {
                tokens.push(t);
            }
            let mut peaks = Json::arr();
            for &p in &r.kv_peak {
                peaks.push(p);
            }
            policies.push(
                Json::obj()
                    .set("policy", r.policy.id())
                    .set("completed", r.completed)
                    .set("rejected", r.rejected)
                    .set("req_per_s", r.req_per_s)
                    .set("tok_per_s", r.tok_per_s)
                    .set("ttft_p50_ns", r.ttft_p50_ns)
                    .set("ttft_p99_ns", r.ttft_p99_ns)
                    .set("replica_token_skew", r.token_skew)
                    .set("max_flow_share", r.max_flow_share)
                    .set("replica_tokens", tokens)
                    .set("replica_kv_peak", peaks),
            );
        }
        let mut dp = Json::arr();
        for r in &self.dp_rows {
            dp.push(
                Json::obj()
                    .set("id", r.condition.id())
                    .set("detected", r.detected)
                    .set(
                        "latency_ns",
                        r.latency_ns.map(|n| Json::Int(n as i64)).unwrap_or(Json::Null),
                    )
                    .set("healthy_tok_per_s", r.healthy_tok_per_s)
                    .set("injected_tok_per_s", r.injected_tok_per_s)
                    .set("mitigated_tok_per_s", r.mitigated_tok_per_s)
                    .set("recovery", r.recovery.map(Json::Num).unwrap_or(Json::Null))
                    .set("injected_token_skew", r.injected_token_skew)
                    .set("mitigated_token_skew", r.mitigated_token_skew)
                    .set("actions", r.actions),
            );
        }
        Json::obj()
            .set("schema", "dpulens.fleet.v1")
            .set("replicas", self.replicas)
            .set("base_seed", self.base_seed)
            .set("policies", policies)
            .set("dp_conditions", dp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_base_cfg_scales_the_cluster() {
        let cfg = fleet_base_cfg(4);
        assert_eq!(cfg.cluster.n_nodes, 8);
        assert_eq!(cfg.engine.nodes_per_stage, 1);
        assert_eq!(cfg.victim_replica, 3);
        cfg.cluster.validate().unwrap();
        let plans =
            crate::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage);
        assert_eq!(plans.len(), 4);
    }

    #[test]
    fn cells_enumerate_policies_then_dp_triples() {
        let fc = FleetConfig::new(2);
        let v = cells(&fc);
        assert_eq!(v.len(), fc.policies.len() + 3 * DP_CONDITIONS.len());
        assert_eq!(v[0], FleetCell::Policy(RoutePolicy::FlowHash));
        let base_idx = fc.policies.len();
        assert_eq!(v[base_idx], FleetCell::DpHealthy(Condition::Dp1RouterFlowSkew));
        assert_eq!(v[base_idx + 1], FleetCell::DpInjected(Condition::Dp1RouterFlowSkew));
        assert_eq!(v[base_idx + 2], FleetCell::DpMitigated(Condition::Dp1RouterFlowSkew));
        // The triple shares one shaped config; only inject/mitigate differ.
        let healthy = cell_cfg(&fc, v[base_idx]);
        let inj = cell_cfg(&fc, v[base_idx + 1]);
        let mit = cell_cfg(&fc, v[base_idx + 2]);
        assert_eq!(inj.engine.route_policy, RoutePolicy::FlowHash);
        assert!(healthy.inject.is_none() && !healthy.mitigate);
        assert!(inj.inject.is_some() && !inj.mitigate);
        assert!(mit.inject.is_some() && mit.mitigate);
        assert_eq!(healthy.duration, inj.duration);
        assert_eq!(healthy.engine.profile.name, inj.engine.profile.name);
        assert!(inj.duration > fc.base.duration);
        // Saturation-sensitive DP cells promote the compute-dominated profile.
        assert_eq!(inj.engine.profile.name, "7b");
        let dp2 = cell_cfg(&fc, FleetCell::DpInjected(Condition::Dp2HotReplicaKv));
        assert_eq!(dp2.engine.profile.name, "small");
    }
}
