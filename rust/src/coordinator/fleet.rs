//! Fleet sweep: the replicas × routing-policy serving study plus the DP1-DP3
//! data-parallel condition experiments (inject → detect → mitigate) — the
//! engine behind `dpulens fleet`.
//!
//! A fleet world uses single-node pipeline stages so the default 4-GPU nodes
//! yield `2 × replicas` nodes and `replicas` data-parallel lanes. The sweep
//! runs, fanned out over `util::par` worker threads:
//!
//! * one healthy cell per routing policy (per-replica skew columns), and
//! * per DP condition, a healthy / injected / mitigated triple on the
//!   skew-prone affinity-hash baseline — all three on the same shaped
//!   config, so recovery is measured against a like-for-like reference.
//!
//! Aggregation order is fixed by the cell list, so the JSON form is
//! byte-identical across runs and `--threads` values.

use crate::cluster::{ReplicaRole, ReplicaShape};
use crate::coordinator::experiment::{inject_time, standard_cfg};
use crate::coordinator::scenario::{RunResult, ScenarioCfg};
use crate::coordinator::snapshot::{self, ReuseStats};
use crate::dpu::detectors::{Condition, DP_CONDITIONS, PD_CONDITIONS, TD_CONDITIONS};
use crate::engine::router::ALL_POLICIES;
use crate::engine::RoutePolicy;
use crate::sim::{SimDur, SimTime};
use crate::util::json::Json;
use crate::util::par::resolve_threads;
use crate::util::table::{fmt_ns, Table};

/// Extra measurement time DP cells get past the standard duration, so the
/// post-mitigation phase is long enough for throughput to visibly recover.
const DP_EXTRA_MS: u64 = 1600;

/// Fleet-sweep configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Base scenario every cell derives from (already fleet-shaped).
    pub base: ScenarioCfg,
    pub replicas: usize,
    /// Routing policies swept for the healthy study.
    pub policies: Vec<RoutePolicy>,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Append the phase-disaggregation study (colocated-vs-disagg twin
    /// cells + the PD1-PD3 triples); bumps the JSON schema to v2.
    pub disagg: bool,
    /// Append the multi-pool study (`--prefill-pools` / `--decode-pools`):
    /// an arbitrary K×M pool topology with the full fleet condition family
    /// run as catalog-driven triples; bumps the JSON schema to v3.
    pub multipool: Option<MultiPoolSpec>,
    /// Append the degraded-telemetry study (`--telemetry-faults`): TD1-TD3
    /// triples on the telemetry-weighted routing baseline, reporting the
    /// freshness watchdog's fallback-ladder transitions alongside detection;
    /// bumps the JSON schema to v4.
    pub telemetry_faults: bool,
    /// Run every cell from scratch instead of forking shared
    /// pre-injection prefixes (`--no-reuse`; equivalence debugging).
    pub no_reuse: bool,
}

/// Knobs of the multi-pool study topology.
#[derive(Debug, Clone, Copy)]
pub struct MultiPoolSpec {
    pub replicas: usize,
    pub prefill_pools: usize,
    pub decode_pools: usize,
}

impl MultiPoolSpec {
    /// Check the topology is buildable (enough decode replicas for the
    /// requested pools) — the CLI's graceful-error path; the shape builder
    /// asserts the same invariant.
    pub fn validate(&self) -> Result<(), String> {
        if self.replicas < 2 || self.prefill_pools < 1 || self.decode_pools < 1 {
            return Err(format!(
                "multi-pool topology needs >= 2 replicas and >= 1 pool per side \
                 (got {} replicas, {} prefill pools, {} decode pools)",
                self.replicas, self.prefill_pools, self.decode_pools
            ));
        }
        if self.decode_pools > self.prefill_pools {
            return Err(format!(
                "{} decode pools need at least as many prefill pools (got {}): \
                 handoffs pair prefill pool p with decode pool p % M, so a decode \
                 pool beyond the prefill pool count would never receive traffic",
                self.decode_pools, self.prefill_pools
            ));
        }
        let n_prefill = self.prefill_pools.max(self.replicas.div_ceil(3));
        let n_decode = self.replicas.saturating_sub(n_prefill);
        if n_decode < self.decode_pools.max(1) {
            return Err(format!(
                "{} replicas leave {n_decode} decode replicas ({n_prefill} go to the \
                 prefill tier): too few for {} decode pools — raise --replicas or \
                 lower the pool counts",
                self.replicas, self.decode_pools
            ));
        }
        Ok(())
    }
}

impl FleetConfig {
    pub fn new(replicas: usize) -> Self {
        FleetConfig {
            base: fleet_base_cfg(replicas),
            replicas,
            policies: ALL_POLICIES.to_vec(),
            threads: 0,
            disagg: false,
            multipool: None,
            telemetry_faults: false,
            no_reuse: false,
        }
    }
}

/// Base scenario for an `n`-replica fleet: single-node pipeline stages
/// (2 nodes per replica on the default spec), arrival scaled to the fleet,
/// and the victim replica set to the last (non-zero) lane.
pub fn fleet_base_cfg(replicas: usize) -> ScenarioCfg {
    assert!(replicas >= 1);
    let mut cfg = standard_cfg();
    cfg.cluster.n_nodes = 2 * replicas;
    cfg.cluster.pp_degree = 2;
    cfg.engine.nodes_per_stage = 1;
    cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 250.0 * replicas as f64 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 32 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    cfg.victim_replica = replicas.saturating_sub(1);
    cfg
}

/// The canonical two-pool topology of the disaggregation study: one TP8×PP1
/// prefill replica beside two TP4×PP2 decode replicas on six nodes.
pub fn disagg_shapes() -> Vec<ReplicaShape> {
    vec![
        ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
        ReplicaShape::new(ReplicaRole::Decode, 4, 2),
        ReplicaShape::new(ReplicaRole::Decode, 4, 2),
    ]
}

/// Base scenario for the phase-disaggregation study. The 7b cost profile
/// makes prefill genuinely compute-dominated (the phase asymmetry the
/// topology exists for); short prompts + short outputs keep the healthy
/// fleet comfortably inside both pools' capacity.
pub fn disagg_base_cfg() -> ScenarioCfg {
    let mut cfg = standard_cfg();
    cfg.cluster.n_nodes = 6;
    cfg.cluster.pp_degree = 2;
    cfg.engine.profile = crate::engine::preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.engine.shapes = Some(disagg_shapes());
    cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 500.0 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    // PD injections that resolve a victim node target the second decode
    // replica, mirroring the DP sweep's last-lane convention.
    cfg.victim_replica = 2;
    cfg.duration = cfg.duration + SimDur::from_ms(DP_EXTRA_MS);
    cfg
}

/// The colocated twin of [`disagg_base_cfg`]: same six nodes, same cost
/// profile and workload, but three TP4×PP2 colocated replicas — the
/// topology-comparison baseline.
pub fn colocated_twin_cfg() -> ScenarioCfg {
    let mut cfg = disagg_base_cfg();
    cfg.engine.shapes = Some(vec![ReplicaShape::new(ReplicaRole::Colocated, 4, 2); 3]);
    cfg
}

/// Replica shapes of an N-replica multi-pool topology: single-node TP4×PP1
/// replicas, the first `max(K, ceil(N/3))` prefill and the rest decode —
/// one node per replica keeps arbitrary replica counts cheap to simulate.
pub fn multipool_shapes(mp: &MultiPoolSpec) -> Vec<ReplicaShape> {
    let n_prefill = mp.prefill_pools.max(mp.replicas.div_ceil(3));
    let n_decode = mp.replicas.saturating_sub(n_prefill);
    assert!(
        n_decode >= mp.decode_pools && n_decode >= 1,
        "{} replicas leave {n_decode} decode replicas: too few for {} decode pools",
        mp.replicas,
        mp.decode_pools
    );
    let mut shapes = vec![ReplicaShape::new(ReplicaRole::Prefill, 4, 1); n_prefill];
    shapes.extend(vec![ReplicaShape::new(ReplicaRole::Decode, 4, 1); n_decode]);
    shapes
}

/// Base scenario of the multi-pool study: N single-node replicas on N
/// nodes, split into K admission pools and M handoff pools, on the
/// compute-dominated 7b profile (so fleet pathologies move throughput).
/// Demand scales with the prefill-side GPU count, mirroring the calibrated
/// disagg study (~60 req/s per prefill GPU keeps the healthy fleet inside
/// both pools' capacity with a decisive margin for 2-3x injection surges).
pub fn multipool_base_cfg(mp: &MultiPoolSpec) -> ScenarioCfg {
    let shapes = multipool_shapes(mp);
    let n_prefill = shapes.iter().filter(|s| s.role == ReplicaRole::Prefill).count();
    let mut cfg = standard_cfg();
    cfg.cluster.n_nodes = mp.replicas;
    cfg.cluster.pp_degree = 1;
    cfg.engine.profile = crate::engine::preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.engine.shapes = Some(shapes);
    cfg.engine.prefill_pools = mp.prefill_pools;
    cfg.engine.decode_pools = mp.decode_pools;
    cfg.workload.arrival =
        crate::sim::dist::Arrival::Poisson { rate: 60.0 * (n_prefill * 4) as f64 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    // Victimize the last decode replica (the last lane of the last decode
    // pool), mirroring the DP/PD sweeps' last-lane convention.
    cfg.victim_replica = mp.replicas - 1;
    cfg.duration = cfg.duration + SimDur::from_ms(DP_EXTRA_MS);
    cfg
}

/// Every fleet-sensed condition (DP + PD families), catalog order.
pub fn fleet_conditions() -> Vec<Condition> {
    crate::conditions::all_specs()
        .filter(|s| {
            matches!(
                s.binding,
                crate::conditions::DetectorBinding::FleetDp { .. }
                    | crate::conditions::DetectorBinding::FleetPd { .. }
            )
        })
        .map(|s| s.condition)
        .collect()
}

/// The pool partition a multi-pool spec builds (shapes' roles × K × M).
fn multipool_pools(mp: &MultiPoolSpec) -> crate::engine::PoolTopology {
    let roles: Vec<ReplicaRole> = multipool_shapes(mp).iter().map(|s| s.role).collect();
    crate::engine::PoolTopology::build(&roles, mp.prefill_pools, mp.decode_pools)
}

/// Can `c`'s fleet rule ever fire on this pool partition? Rules declare
/// their smallest judgeable pool in the catalog (`min_pool`: 2 for
/// peer-comparison skew, 1 for aggregates); a topology whose every pool of
/// the rule's scope is smaller makes the rule structurally inert, and
/// running its triple would be three guaranteed-negative simulations.
fn mp_applicable(c: Condition, pools: &crate::engine::PoolTopology) -> bool {
    use crate::conditions::{DetectorBinding, FleetScope};
    let (scope, min_pool) = match crate::conditions::spec(c).binding {
        DetectorBinding::FleetDp { scope, min_pool, .. }
        | DetectorBinding::FleetPd { scope, min_pool, .. } => (scope, min_pool),
        DetectorBinding::NodeWindow => return false,
    };
    match scope {
        FleetScope::PerPrefillPool => pools.prefill_pools.iter().any(|p| p.len() >= min_pool),
        FleetScope::PerDecodePool => pools.decode_pools.iter().any(|p| p.len() >= min_pool),
        FleetScope::DecodeUnion => pools.decode_members.len() >= min_pool,
    }
}

/// The fleet conditions a multi-pool topology can host, and those it
/// structurally cannot (reported, never silently dropped).
pub fn multipool_conditions(mp: &MultiPoolSpec) -> (Vec<Condition>, Vec<Condition>) {
    let pools = multipool_pools(mp);
    fleet_conditions().into_iter().partition(|&c| mp_applicable(c, &pools))
}

/// Does `c`'s triple shape its own config? Unshaped conditions run on a
/// config byte-identical to the topology cell (cell_cfg's explicit DP
/// affinity baseline is already the multipool default), so their healthy
/// reference IS the topology cell — no dedicated healthy simulation.
fn mp_has_dedicated_healthy(c: Condition) -> bool {
    crate::conditions::spec(c).shape_fleet.is_some()
}

/// One cell of the fleet sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FleetCell {
    Policy(RoutePolicy),
    /// The DP condition's shaped config WITHOUT the injection — the
    /// like-for-like recovery baseline.
    DpHealthy(Condition),
    DpInjected(Condition),
    DpMitigated(Condition),
    /// Topology comparison: the colocated twin of the disagg base.
    DisaggColocatedTwin,
    /// Topology comparison: the healthy disaggregated base.
    DisaggHealthy,
    /// PD condition triples on the disaggregated base (same healthy /
    /// injected / mitigated discipline as the DP rows).
    PdHealthy(Condition),
    PdInjected(Condition),
    PdMitigated(Condition),
    /// Multi-pool study: the healthy K×M topology cell.
    MpTopology,
    /// Multi-pool condition triples (every fleet-sensed condition, DP + PD,
    /// catalog order).
    MpHealthy(Condition),
    MpInjected(Condition),
    MpMitigated(Condition),
    /// Degraded-telemetry triples (TD1-TD3) on the telemetry-weighted
    /// routing baseline — the policy the fallback ladder protects.
    TdHealthy(Condition),
    TdInjected(Condition),
    TdMitigated(Condition),
}

/// The shared shaping every cell of one DP condition's triple (healthy /
/// injected / mitigated) runs on, so their throughputs are comparable. The
/// per-condition recipe is catalog knowledge (`shape_fleet` on each
/// [`crate::conditions::ConditionSpec`]); this applies it on the sweep base.
fn dp_shaped(fc: &FleetConfig, c: Condition) -> ScenarioCfg {
    let mut cfg = fc.base.clone();
    // DP conditions are studied on the skew-prone affinity baseline.
    cfg.engine.route_policy = RoutePolicy::FlowHash;
    cfg.duration = cfg.duration + SimDur::from_ms(DP_EXTRA_MS);
    if let Some(shape) = crate::conditions::spec(c).shape_fleet {
        shape(&mut cfg);
    }
    cfg
}

/// Per-condition shaping of the PD triples (the catalog's `shape_fleet`),
/// applied on top of [`disagg_base_cfg`] (the healthy cell shares the
/// shaping, so recovery is measured like for like).
fn pd_shaped(c: Condition) -> ScenarioCfg {
    let mut cfg = disagg_base_cfg();
    if let Some(shape) = crate::conditions::spec(c).shape_fleet {
        shape(&mut cfg);
    }
    cfg
}

/// The shared shaping of one TD condition's triple: the sweep base on the
/// telemetry-weighted routing policy — the only policy whose picks consume
/// the gauges the injection degrades, so the fallback ladder has something
/// to protect. The extra measurement time leaves room for inject → detect →
/// mitigate → ladder recovery inside one cell.
fn td_shaped(fc: &FleetConfig, c: Condition) -> ScenarioCfg {
    let mut cfg = fc.base.clone();
    cfg.engine.route_policy = RoutePolicy::WeightedTelemetry;
    cfg.duration = cfg.duration + SimDur::from_ms(DP_EXTRA_MS);
    if let Some(shape) = crate::conditions::spec(c).shape_fleet {
        shape(&mut cfg);
    }
    cfg
}

fn cell_cfg(fc: &FleetConfig, cell: FleetCell) -> ScenarioCfg {
    let mut cfg = cell_cfg_inner(fc, cell);
    // Engine plumbing follows the sweep's base config even in the cells
    // that build their topology from scratch: the equivalence suite pins
    // `base.calendar` and expects every cell to honor it.
    cfg.calendar = fc.base.calendar;
    cfg.observe_threads = fc.base.observe_threads;
    cfg
}

fn cell_cfg_inner(fc: &FleetConfig, cell: FleetCell) -> ScenarioCfg {
    match cell {
        FleetCell::Policy(p) => {
            let mut cfg = fc.base.clone();
            cfg.engine.route_policy = p;
            cfg
        }
        FleetCell::DpHealthy(c) => dp_shaped(fc, c),
        FleetCell::DpInjected(c) | FleetCell::DpMitigated(c) => {
            let mut cfg = dp_shaped(fc, c);
            cfg.inject = Some((c, inject_time(&cfg)));
            cfg.mitigate = matches!(cell, FleetCell::DpMitigated(_));
            cfg
        }
        // The disagg study shapes its own topology/workload/duration, but
        // inherits the sweep's seed so `--seed` varies its replicates too
        // (and the report's base_seed stays truthful for the v2 section).
        FleetCell::DisaggColocatedTwin => {
            let mut cfg = colocated_twin_cfg();
            cfg.seed = fc.base.seed;
            cfg
        }
        FleetCell::DisaggHealthy => {
            let mut cfg = disagg_base_cfg();
            cfg.seed = fc.base.seed;
            cfg
        }
        FleetCell::PdHealthy(c) => {
            let mut cfg = pd_shaped(c);
            cfg.seed = fc.base.seed;
            cfg
        }
        FleetCell::PdInjected(c) | FleetCell::PdMitigated(c) => {
            let mut cfg = pd_shaped(c);
            cfg.seed = fc.base.seed;
            cfg.inject = Some((c, inject_time(&cfg)));
            cfg.mitigate = matches!(cell, FleetCell::PdMitigated(_));
            cfg
        }
        FleetCell::MpTopology => {
            let mp = fc.multipool.as_ref().expect("multipool cells need a spec");
            let mut cfg = multipool_base_cfg(mp);
            cfg.seed = fc.base.seed;
            cfg
        }
        FleetCell::MpHealthy(c) | FleetCell::MpInjected(c) | FleetCell::MpMitigated(c) => {
            let mp = fc.multipool.as_ref().expect("multipool cells need a spec");
            let mut cfg = multipool_base_cfg(mp);
            cfg.seed = fc.base.seed;
            // DP conditions are studied on the skew-prone affinity baseline
            // (the admission default is already FlowHash; set explicitly for
            // parity with the v1 DP triples), with the catalog shaping the
            // triple like for like.
            if crate::conditions::spec(c).family == crate::conditions::Family::DataParallel {
                cfg.engine.route_policy = RoutePolicy::FlowHash;
            }
            if let Some(shape) = crate::conditions::spec(c).shape_fleet {
                shape(&mut cfg);
            }
            if !matches!(cell, FleetCell::MpHealthy(_)) {
                cfg.inject = Some((c, inject_time(&cfg)));
                cfg.mitigate = matches!(cell, FleetCell::MpMitigated(_));
            }
            cfg
        }
        FleetCell::TdHealthy(c) => td_shaped(fc, c),
        FleetCell::TdInjected(c) | FleetCell::TdMitigated(c) => {
            let mut cfg = td_shaped(fc, c);
            cfg.inject = Some((c, inject_time(&cfg)));
            cfg.mitigate = matches!(cell, FleetCell::TdMitigated(_));
            cfg
        }
    }
}

/// The disagg cell block, in the exact order `disagg_report_from` decodes:
/// topology twins first, then the PD triples. Shared by the full sweep and
/// the standalone study so the two cannot drift.
fn disagg_cells() -> Vec<FleetCell> {
    let mut v = vec![FleetCell::DisaggColocatedTwin, FleetCell::DisaggHealthy];
    for c in PD_CONDITIONS {
        v.push(FleetCell::PdHealthy(c));
        v.push(FleetCell::PdInjected(c));
        v.push(FleetCell::PdMitigated(c));
    }
    v
}

/// The multi-pool cell block, in the exact order `multipool_report_from`
/// decodes: the healthy topology cell, then — per applicable fleet
/// condition, catalog order — an optional dedicated healthy cell (only
/// when the triple shapes its own config) and the injected/mitigated pair.
fn multipool_cells(mp: &MultiPoolSpec) -> Vec<FleetCell> {
    let (run, _skipped) = multipool_conditions(mp);
    let mut v = vec![FleetCell::MpTopology];
    for c in run {
        if mp_has_dedicated_healthy(c) {
            v.push(FleetCell::MpHealthy(c));
        }
        v.push(FleetCell::MpInjected(c));
        v.push(FleetCell::MpMitigated(c));
    }
    v
}

/// The degraded-telemetry cell block, in the exact order
/// `telemetry_report_from` decodes: one healthy / injected / mitigated
/// triple per TD condition. Shared by the full sweep and the standalone
/// study so the two cannot drift.
fn td_cells() -> Vec<FleetCell> {
    let mut v = Vec::new();
    for c in TD_CONDITIONS {
        v.push(FleetCell::TdHealthy(c));
        v.push(FleetCell::TdInjected(c));
        v.push(FleetCell::TdMitigated(c));
    }
    v
}

fn cells(fc: &FleetConfig) -> Vec<FleetCell> {
    let mut v: Vec<FleetCell> = fc.policies.iter().map(|&p| FleetCell::Policy(p)).collect();
    for c in DP_CONDITIONS {
        v.push(FleetCell::DpHealthy(c));
        v.push(FleetCell::DpInjected(c));
        v.push(FleetCell::DpMitigated(c));
    }
    if fc.disagg {
        v.extend(disagg_cells());
    }
    if let Some(mp) = &fc.multipool {
        v.extend(multipool_cells(mp));
    }
    if fc.telemetry_faults {
        v.extend(td_cells());
    }
    v
}

/// Compact per-cell result shipped back from a worker thread.
#[derive(Debug, Clone)]
struct CellOutcome {
    completed: u64,
    rejected: u64,
    tok_per_s: f64,
    req_per_s: f64,
    ttft_p50_ns: f64,
    ttft_p99_ns: f64,
    token_skew: f64,
    max_flow_share: f64,
    replica_tokens: Vec<u64>,
    kv_peak: Vec<f64>,
    detected: bool,
    latency_ns: Option<u64>,
    actions: u64,
    /// Telemetry events the cell's pipeline delivered (perf accounting).
    events: u64,
    /// KV handoffs completed / logical bytes delivered (zero when colocated).
    handoffs: u64,
    handoff_bytes: u64,
    /// Per (prefill pool, decode pool) launches and bytes (multi-pool cells).
    handoff_pairs: Vec<(u32, u32, u64, u64)>,
    /// Fallback-ladder transitions `(window, level)` and fault-layer loss
    /// accounting — empty/zero on every cell that never engages a telemetry
    /// fault (only the TD rows consume these).
    ladder: Vec<(u64, u8)>,
    fault_dropped: u64,
    fault_held: u64,
}

/// Simulate every cell through the snapshot runner (cells whose worlds are
/// identical until injection simulate their shared pre-injection prefix once
/// and fork per-cell branches) and score the results in cell order. Configs
/// are fingerprinted AFTER `cell_cfg`, so the sweep-level calendar and
/// observe-thread overrides are part of the prefix identity.
fn run_cells(
    fc: &FleetConfig,
    cell_list: &[FleetCell],
    threads: usize,
    no_reuse: bool,
) -> (Vec<CellOutcome>, ReuseStats) {
    let cfgs: Vec<ScenarioCfg> = cell_list.iter().map(|&cell| cell_cfg(fc, cell)).collect();
    let (results, reuse) = snapshot::run_all(cfgs, threads, no_reuse);
    let outcomes =
        cell_list.iter().zip(results.iter()).map(|(&cell, res)| score_cell(cell, res)).collect();
    (outcomes, reuse)
}

fn score_cell(cell: FleetCell, res: &RunResult) -> CellOutcome {
    let injected = match cell {
        FleetCell::DpInjected(c)
        | FleetCell::DpMitigated(c)
        | FleetCell::PdInjected(c)
        | FleetCell::PdMitigated(c)
        | FleetCell::MpInjected(c)
        | FleetCell::MpMitigated(c) => Some(c),
        _ => None,
    };
    let t0 = res.injected_at.unwrap_or(SimTime(u64::MAX));
    let detected = injected
        .map(|c| res.detections.iter().any(|d| d.condition == c && d.at >= t0))
        .unwrap_or(false);
    let latency_ns = injected.and_then(|c| res.detection_latency(c)).map(|d| d.ns());
    let total_routed: u64 = res.replica_routed.iter().sum();
    let max_flow_share = if total_routed == 0 {
        0.0
    } else {
        *res.replica_routed.iter().max().unwrap() as f64 / total_routed as f64
    };
    CellOutcome {
        completed: res.metrics.completed,
        rejected: res.metrics.rejected,
        tok_per_s: res.metrics.tok_per_s(),
        req_per_s: res.metrics.req_per_s(),
        ttft_p50_ns: res.metrics.ttft_ns.p50(),
        ttft_p99_ns: res.metrics.ttft_ns.p99(),
        token_skew: res.metrics.replica_token_skew(),
        max_flow_share,
        replica_tokens: res.metrics.per_replica.iter().map(|l| l.tokens_out).collect(),
        kv_peak: res.replica_kv_peak.clone(),
        detected,
        latency_ns,
        actions: res.actions.len() as u64,
        events: res.telemetry_published,
        handoffs: res.handoffs.completed,
        handoff_bytes: res.handoffs.bytes_delivered,
        handoff_pairs: res
            .handoffs
            .per_pair
            .iter()
            .map(|p| (p.prefill_pool, p.decode_pool, p.started, p.bytes_sent))
            .collect(),
        ladder: res.ladder_transitions.clone(),
        fault_dropped: res.fault_dropped,
        fault_held: res.fault_held_at_end,
    }
}

/// One healthy routing-policy row.
#[derive(Debug, Clone)]
pub struct PolicyRow {
    pub policy: RoutePolicy,
    pub completed: u64,
    pub rejected: u64,
    pub req_per_s: f64,
    pub tok_per_s: f64,
    pub ttft_p50_ns: f64,
    pub ttft_p99_ns: f64,
    /// Max-over-mean token share across replicas (1.0 = balanced).
    pub token_skew: f64,
    /// Largest per-replica share of routed arrivals.
    pub max_flow_share: f64,
    pub replica_tokens: Vec<u64>,
    pub kv_peak: Vec<f64>,
}

/// One DP condition's inject → detect → mitigate row.
#[derive(Debug, Clone)]
pub struct DpRow {
    pub condition: Condition,
    pub detected: bool,
    pub latency_ns: Option<u64>,
    pub healthy_tok_per_s: f64,
    pub injected_tok_per_s: f64,
    pub mitigated_tok_per_s: f64,
    /// Fraction of lost throughput the closed loop recovered, measured
    /// against the same shaped config WITHOUT the injection (clamped to
    /// 0..1.5). For conditions whose injection itself raises demand (DP1's
    /// flash crowd), the baseline reflects pre-surge demand, so the value
    /// saturates high once the mitigated fleet outserves it.
    pub recovery: Option<f64>,
    pub injected_token_skew: f64,
    pub mitigated_token_skew: f64,
    /// Mitigation actions taken in the mitigated run.
    pub actions: u64,
}

/// The phase-disaggregation study: the colocated-vs-disagg topology twins
/// plus the PD1-PD3 inject → detect → mitigate triples.
#[derive(Debug)]
pub struct DisaggReport {
    /// Healthy throughput/latency of the colocated twin topology.
    pub colocated_tok_per_s: f64,
    pub colocated_ttft_p50_ns: f64,
    /// Healthy throughput/latency of the disaggregated base topology.
    pub disagg_tok_per_s: f64,
    pub disagg_ttft_p50_ns: f64,
    /// Healthy disagg cell's KV-handoff volume (completed / logical bytes).
    pub handoffs: u64,
    pub handoff_bytes: u64,
    /// PD condition rows (same shape/discipline as the DP rows).
    pub pd_rows: Vec<DpRow>,
}

/// The multi-pool study: an arbitrary K×M pool topology with per-pool DP
/// scoping, per-pool-pair handoff accounting, and the full fleet condition
/// family as catalog-driven triples.
#[derive(Debug)]
pub struct MultiPoolReport {
    pub replicas: usize,
    pub prefill_pool_count: usize,
    pub decode_pool_count: usize,
    /// Shape label per replica, lane order.
    pub topology: Vec<String>,
    /// Pool membership (global replica indices) the study ran on — the
    /// partition every DP/PD comparison was scoped to.
    pub prefill_pools: Vec<Vec<usize>>,
    pub decode_pools: Vec<Vec<usize>>,
    /// Healthy topology cell.
    pub healthy_tok_per_s: f64,
    pub healthy_ttft_p50_ns: f64,
    pub handoffs: u64,
    pub handoff_bytes: u64,
    /// Healthy cell's (prefill pool, decode pool, handoffs started, bytes)
    /// traffic matrix.
    pub handoff_pairs: Vec<(u32, u32, u64, u64)>,
    /// One inject → detect → mitigate row per applicable fleet condition
    /// (DP + PD, catalog order).
    pub rows: Vec<DpRow>,
    /// Conditions whose rule is structurally inert on this topology (every
    /// pool of its scope smaller than the catalog's `min_pool`) — reported
    /// rather than run as guaranteed-negative triples.
    pub skipped: Vec<Condition>,
}

/// One TD condition's degraded-telemetry row: detection plus how the
/// router's fallback ladder behaved while the telemetry plane was under
/// fault — the injected cell's ladder path and the mitigated cell's
/// recovery level.
#[derive(Debug, Clone)]
pub struct TdRow {
    pub condition: Condition,
    pub detected: bool,
    pub latency_ns: Option<u64>,
    pub healthy_tok_per_s: f64,
    pub injected_tok_per_s: f64,
    pub mitigated_tok_per_s: f64,
    /// Injected/healthy throughput ratio — how much serving the ladder held
    /// onto while routing on degraded (or no) telemetry.
    pub throughput_held: f64,
    /// `(window, level)` fallback-ladder transitions of the injected cell.
    pub ladder_transitions: Vec<(u64, u8)>,
    /// Deepest fallback level the injected cell reached.
    pub max_ladder_level: u8,
    /// Ladder level the mitigated cell ended on (0 = fully recovered
    /// through the hysteresis streaks).
    pub recovered_level: u8,
    /// Fault-layer loss accounting of the injected cell.
    pub fault_dropped: u64,
    pub fault_held: u64,
    /// Mitigation actions taken in the mitigated run.
    pub actions: u64,
}

/// The degraded-telemetry study: TD1-TD3 inject → detect → mitigate triples
/// on the telemetry-weighted baseline, with the fallback-ladder trace.
#[derive(Debug)]
pub struct TelemetryReport {
    pub rows: Vec<TdRow>,
}

/// Everything a fleet sweep produces.
#[derive(Debug)]
pub struct FleetReport {
    pub replicas: usize,
    pub base_seed: u64,
    pub policy_rows: Vec<PolicyRow>,
    pub dp_rows: Vec<DpRow>,
    /// The phase-disaggregation section (`--disagg`; bumps JSON to v2).
    pub disagg: Option<DisaggReport>,
    /// The multi-pool section (`--prefill-pools`/`--decode-pools`; bumps
    /// the JSON to v3).
    pub multipool: Option<MultiPoolReport>,
    /// The degraded-telemetry section (`--telemetry-faults`; bumps the
    /// JSON to v4).
    pub telemetry: Option<TelemetryReport>,
    pub cells_run: usize,
    pub threads_used: usize,
    /// Wall-clock of the parallel cell sweep, ms. Perf metadata: reported
    /// in the human output and `dpulens perf`, excluded from `to_json` so
    /// the fleet JSON stays byte-identical across thread counts.
    pub elapsed_ms: f64,
    /// Telemetry events delivered across all cells' pipelines.
    pub events_total: u64,
    /// Snapshot-and-branch prefix-reuse accounting for the sweep. Perf
    /// metadata like `elapsed_ms`: surfaced by the human output and
    /// `dpulens perf`, excluded from `to_json` so the fleet JSON stays
    /// byte-identical whether or not reuse was enabled.
    pub reuse: ReuseStats,
}

impl FleetReport {
    /// Pipeline ingest throughput of the whole sweep (events/sec).
    pub fn events_per_sec(&self) -> f64 {
        crate::util::perf::events_per_sec(self.events_total, self.elapsed_ms)
    }
}

/// Execute the fleet sweep in parallel and aggregate in cell order.
/// Wall-clock and events/sec land in the report's perf fields (excluded
/// from the deterministic JSON; see `FleetReport::to_json`).
pub fn run_fleet(fc: &FleetConfig) -> FleetReport {
    let cell_list = cells(fc);
    let threads_used = resolve_threads(fc.threads, cell_list.len());
    let timer = crate::util::perf::PhaseTimer::start();
    let (mut outcomes, reuse) = run_cells(fc, &cell_list, fc.threads, fc.no_reuse);
    let elapsed_ms = timer.total_ms();
    let events_total: u64 = outcomes.iter().map(|o| o.events).sum();

    let n_pol = fc.policies.len();
    // The TD block rides at the very end of the cell list, so peeling it
    // off first leaves the v1/v2/v3 split chain untouched.
    let td_outcomes = if fc.telemetry_faults {
        outcomes.split_off(outcomes.len() - 3 * TD_CONDITIONS.len())
    } else {
        Vec::new()
    };
    // The DP triples only need scalar outcomes; the policy rows take the
    // per-replica vectors by move (no re-clone of worker results).
    let mut dp_outcomes = outcomes.split_off(n_pol);
    let mut disagg_outcomes = dp_outcomes.split_off(3 * DP_CONDITIONS.len());
    let mp_outcomes = if fc.disagg {
        disagg_outcomes.split_off(2 + 3 * PD_CONDITIONS.len())
    } else {
        std::mem::take(&mut disagg_outcomes)
    };
    let policy_rows: Vec<PolicyRow> = fc
        .policies
        .iter()
        .zip(outcomes)
        .map(|(&policy, o)| PolicyRow {
            policy,
            completed: o.completed,
            rejected: o.rejected,
            req_per_s: o.req_per_s,
            tok_per_s: o.tok_per_s,
            ttft_p50_ns: o.ttft_p50_ns,
            ttft_p99_ns: o.ttft_p99_ns,
            token_skew: o.token_skew,
            max_flow_share: o.max_flow_share,
            replica_tokens: o.replica_tokens,
            kv_peak: o.kv_peak,
        })
        .collect();

    let dp_rows = condition_rows(&dp_outcomes, &DP_CONDITIONS);
    let disagg = if fc.disagg { Some(disagg_report_from(&disagg_outcomes)) } else { None };
    let multipool = fc.multipool.map(|mp| multipool_report_from(&mp, &mp_outcomes));
    let telemetry =
        if fc.telemetry_faults { Some(telemetry_report_from(&td_outcomes)) } else { None };

    FleetReport {
        replicas: fc.replicas,
        base_seed: fc.base.seed,
        policy_rows,
        dp_rows,
        disagg,
        multipool,
        telemetry,
        cells_run: cell_list.len(),
        threads_used,
        elapsed_ms,
        events_total,
        reuse,
    }
}

/// Fold one healthy/injected/mitigated triple into a condition row. The
/// triple runs the SAME shaped config, so the healthy cell is a
/// like-for-like recovery baseline.
fn condition_row(
    c: Condition,
    healthy: &CellOutcome,
    inj: &CellOutcome,
    mit: &CellOutcome,
) -> DpRow {
    let recovery = if healthy.tok_per_s - inj.tok_per_s < 1e-9 {
        Some(1.0)
    } else {
        Some(
            ((mit.tok_per_s - inj.tok_per_s) / (healthy.tok_per_s - inj.tok_per_s))
                .clamp(0.0, 1.5),
        )
    };
    DpRow {
        condition: c,
        detected: inj.detected,
        latency_ns: inj.latency_ns,
        healthy_tok_per_s: healthy.tok_per_s,
        injected_tok_per_s: inj.tok_per_s,
        mitigated_tok_per_s: mit.tok_per_s,
        recovery,
        injected_token_skew: inj.token_skew,
        mitigated_token_skew: mit.token_skew,
        actions: mit.actions,
    }
}

/// Fold back-to-back triples into condition rows.
fn condition_rows(outcomes: &[CellOutcome], conds: &[Condition]) -> Vec<DpRow> {
    assert_eq!(outcomes.len(), 3 * conds.len());
    conds
        .iter()
        .enumerate()
        .map(|(k, &c)| {
            condition_row(c, &outcomes[3 * k], &outcomes[3 * k + 1], &outcomes[3 * k + 2])
        })
        .collect()
}

/// Aggregate the disagg block (twin, healthy, then the PD triples) into a
/// [`DisaggReport`].
fn disagg_report_from(outcomes: &[CellOutcome]) -> DisaggReport {
    assert_eq!(outcomes.len(), 2 + 3 * PD_CONDITIONS.len());
    let twin = &outcomes[0];
    let healthy = &outcomes[1];
    DisaggReport {
        colocated_tok_per_s: twin.tok_per_s,
        colocated_ttft_p50_ns: twin.ttft_p50_ns,
        disagg_tok_per_s: healthy.tok_per_s,
        disagg_ttft_p50_ns: healthy.ttft_p50_ns,
        handoffs: healthy.handoffs,
        handoff_bytes: healthy.handoff_bytes,
        pd_rows: condition_rows(&outcomes[2..], &PD_CONDITIONS),
    }
}

/// Run only the phase-disaggregation study (the `--disagg` block without
/// the v1 policy/DP cells) — the disagg acceptance suite's entrypoint.
/// Uses the default sweep seed; disagg cells only take the seed from the
/// FleetConfig, so the rest of it is irrelevant here.
pub fn run_disagg_study(threads: usize) -> DisaggReport {
    let fc = FleetConfig::new(2);
    let cell_list = disagg_cells();
    let (outcomes, _) = run_cells(&fc, &cell_list, threads, false);
    disagg_report_from(&outcomes)
}

/// Aggregate the multi-pool block (topology cell, then the applicable
/// condition triples — unshaped non-DP triples reuse the topology cell as
/// their healthy reference) into a [`MultiPoolReport`].
fn multipool_report_from(mp: &MultiPoolSpec, outcomes: &[CellOutcome]) -> MultiPoolReport {
    let (run, skipped) = multipool_conditions(mp);
    let topo = &outcomes[0];
    let mut rows = Vec::with_capacity(run.len());
    let mut it = outcomes[1..].iter();
    for c in run {
        let healthy = if mp_has_dedicated_healthy(c) {
            it.next().expect("missing healthy cell")
        } else {
            topo
        };
        let inj = it.next().expect("missing injected cell");
        let mit = it.next().expect("missing mitigated cell");
        rows.push(condition_row(c, healthy, inj, mit));
    }
    assert!(it.next().is_none(), "unconsumed multipool outcomes");
    let shapes = multipool_shapes(mp);
    let pools = multipool_pools(mp);
    MultiPoolReport {
        replicas: mp.replicas,
        prefill_pool_count: pools.prefill_pools.len(),
        decode_pool_count: pools.decode_pools.len(),
        topology: shapes.iter().map(|s| s.label()).collect(),
        prefill_pools: pools.prefill_pools,
        decode_pools: pools.decode_pools,
        healthy_tok_per_s: topo.tok_per_s,
        healthy_ttft_p50_ns: topo.ttft_p50_ns,
        handoffs: topo.handoffs,
        handoff_bytes: topo.handoff_bytes,
        handoff_pairs: topo.handoff_pairs.clone(),
        rows,
        skipped,
    }
}

/// Run only the multi-pool study (the v3 block without the v1/v2 cells) —
/// the multipool acceptance suite's entrypoint.
pub fn run_multipool_study(mp: MultiPoolSpec, threads: usize) -> MultiPoolReport {
    let mut fc = FleetConfig::new(2);
    fc.multipool = Some(mp);
    let cell_list = multipool_cells(&mp);
    let (outcomes, _) = run_cells(&fc, &cell_list, threads, false);
    multipool_report_from(&mp, &outcomes)
}

/// Aggregate the degraded-telemetry block (back-to-back TD triples) into a
/// [`TelemetryReport`]. The ladder trace comes from the injected cell (how
/// deep the fallback went and when); the recovered level from the mitigated
/// cell (whether the hysteresis streaks walked it back to full telemetry).
fn telemetry_report_from(outcomes: &[CellOutcome]) -> TelemetryReport {
    assert_eq!(outcomes.len(), 3 * TD_CONDITIONS.len());
    let rows = TD_CONDITIONS
        .iter()
        .enumerate()
        .map(|(k, &c)| {
            let (healthy, inj, mit) =
                (&outcomes[3 * k], &outcomes[3 * k + 1], &outcomes[3 * k + 2]);
            TdRow {
                condition: c,
                detected: inj.detected,
                latency_ns: inj.latency_ns,
                healthy_tok_per_s: healthy.tok_per_s,
                injected_tok_per_s: inj.tok_per_s,
                mitigated_tok_per_s: mit.tok_per_s,
                throughput_held: if healthy.tok_per_s <= 0.0 {
                    1.0
                } else {
                    inj.tok_per_s / healthy.tok_per_s
                },
                max_ladder_level: inj.ladder.iter().map(|&(_, l)| l).max().unwrap_or(0),
                ladder_transitions: inj.ladder.clone(),
                recovered_level: mit.ladder.last().map(|&(_, l)| l).unwrap_or(0),
                fault_dropped: inj.fault_dropped,
                fault_held: inj.fault_held,
                actions: mit.actions,
            }
        })
        .collect();
    TelemetryReport { rows }
}

/// Run only the degraded-telemetry study (the v4 block without the v1-v3
/// cells) — the telemetry-faults acceptance suite's entrypoint.
pub fn run_telemetry_study(threads: usize) -> TelemetryReport {
    let fc = FleetConfig::new(2);
    let cell_list = td_cells();
    let (outcomes, _) = run_cells(&fc, &cell_list, threads, false);
    telemetry_report_from(&outcomes)
}

impl FleetReport {
    /// Paper-style tables: the policy study and the DP condition study.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new(&format!(
            "Fleet study — {} replicas × routing policies (healthy)",
            self.replicas
        ))
        .header(&[
            "policy", "done", "rej", "req/s", "tok/s", "ttft p50", "ttft p99", "tok skew",
            "max share", "kv peak",
        ]);
        for r in &self.policy_rows {
            let kv_peak = r.kv_peak.iter().cloned().fold(0.0_f64, f64::max);
            t.row(vec![
                r.policy.id().to_string(),
                format!("{}", r.completed),
                format!("{}", r.rejected),
                format!("{:.1}", r.req_per_s),
                format!("{:.0}", r.tok_per_s),
                fmt_ns(r.ttft_p50_ns),
                fmt_ns(r.ttft_p99_ns),
                format!("{:.2}", r.token_skew),
                format!("{:.2}", r.max_flow_share),
                format!("{:.2}", kv_peak),
            ]);
        }
        let mut out = t.render();
        let mut d = Table::new("DP condition family — inject, detect, mitigate (affinity baseline)")
            .header(&[
                "id", "detected", "latency", "healthy tok/s", "injected", "mitigated",
                "recovered", "skew inj->mit", "actions",
            ]);
        for r in &self.dp_rows {
            d.row(vec![
                r.condition.id().to_string(),
                if r.detected { "yes".into() } else { "NO".into() },
                r.latency_ns.map(|n| fmt_ns(n as f64)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.healthy_tok_per_s),
                format!("{:.0}", r.injected_tok_per_s),
                format!("{:.0}", r.mitigated_tok_per_s),
                r.recovery.map(|f| format!("{:.0}%", f * 100.0)).unwrap_or_else(|| "-".into()),
                format!("{:.2} -> {:.2}", r.injected_token_skew, r.mitigated_token_skew),
                format!("{}", r.actions),
            ]);
        }
        out.push_str(&d.render());
        if let Some(disagg) = &self.disagg {
            out.push_str(&disagg.render_tables());
        }
        if let Some(mp) = &self.multipool {
            out.push_str(&mp.render_tables());
        }
        if let Some(t) = &self.telemetry {
            out.push_str(&t.render_tables());
        }
        out
    }

    /// One-paragraph human summary.
    pub fn summary_line(&self) -> String {
        let best = self
            .policy_rows
            .iter()
            .max_by(|a, b| a.tok_per_s.partial_cmp(&b.tok_per_s).unwrap());
        let detected = self.dp_rows.iter().filter(|r| r.detected).count();
        let mut s = format!(
            "fleet of {} replicas: DP conditions detected {}/{}",
            self.replicas,
            detected,
            self.dp_rows.len()
        );
        if let Some(d) = &self.disagg {
            let pd = d.pd_rows.iter().filter(|r| r.detected).count();
            s.push_str(&format!(
                "; PD conditions detected {pd}/{} on the 2-pool topology ({} handoffs)",
                d.pd_rows.len(),
                d.handoffs
            ));
        }
        if let Some(m) = &self.multipool {
            let det = m.rows.iter().filter(|r| r.detected).count();
            s.push_str(&format!(
                "; multi-pool {}x{} study detected {det}/{} fleet conditions",
                m.prefill_pool_count,
                m.decode_pool_count,
                m.rows.len()
            ));
        }
        if let Some(t) = &self.telemetry {
            let det = t.rows.iter().filter(|r| r.detected).count();
            let peak = t.rows.iter().map(|r| r.max_ladder_level).max().unwrap_or(0);
            s.push_str(&format!(
                "; TD conditions detected {det}/{} with fallback-ladder peak level {peak}",
                t.rows.len()
            ));
        }
        if let Some(b) = best {
            s.push_str(&format!(
                "; best healthy policy {} at {:.0} tok/s (token skew {:.2})",
                b.policy.id(),
                b.tok_per_s,
                b.token_skew
            ));
        }
        s
    }

    /// Deterministic JSON: same config + seed ⇒ byte-identical output,
    /// independent of worker-thread count (wallclock/threads excluded).
    /// Without `--disagg` this is schema v1, byte-identical to the pre-PD
    /// output; the disagg section bumps it to `dpulens.fleet.v2`.
    pub fn to_json(&self) -> Json {
        let mut policies = Json::arr();
        for r in &self.policy_rows {
            let mut tokens = Json::arr();
            for &t in &r.replica_tokens {
                tokens.push(t);
            }
            let mut peaks = Json::arr();
            for &p in &r.kv_peak {
                peaks.push(p);
            }
            policies.push(
                Json::obj()
                    .set("policy", r.policy.id())
                    .set("completed", r.completed)
                    .set("rejected", r.rejected)
                    .set("req_per_s", r.req_per_s)
                    .set("tok_per_s", r.tok_per_s)
                    .set("ttft_p50_ns", r.ttft_p50_ns)
                    .set("ttft_p99_ns", r.ttft_p99_ns)
                    .set("replica_token_skew", r.token_skew)
                    .set("max_flow_share", r.max_flow_share)
                    .set("replica_tokens", tokens)
                    .set("replica_kv_peak", peaks),
            );
        }
        let dp = condition_rows_json(&self.dp_rows);
        let schema = if self.telemetry.is_some() {
            "dpulens.fleet.v4"
        } else if self.multipool.is_some() {
            "dpulens.fleet.v3"
        } else if self.disagg.is_some() {
            "dpulens.fleet.v2"
        } else {
            "dpulens.fleet.v1"
        };
        let mut out = Json::obj()
            .set("schema", schema)
            .set("replicas", self.replicas)
            .set("base_seed", self.base_seed)
            .set("policies", policies)
            .set("dp_conditions", dp);
        if let Some(d) = &self.disagg {
            out = out.set("disagg", d.to_json());
        }
        if let Some(m) = &self.multipool {
            out = out.set("multipool", m.to_json());
        }
        if let Some(t) = &self.telemetry {
            out = out.set("telemetry", t.to_json());
        }
        out
    }
}

fn condition_rows_json(rows: &[DpRow]) -> Json {
    let mut arr = Json::arr();
    for r in rows {
        arr.push(
            Json::obj()
                .set("id", r.condition.id())
                .set("detected", r.detected)
                .set(
                    "latency_ns",
                    r.latency_ns.map(|n| Json::Int(n as i64)).unwrap_or(Json::Null),
                )
                .set("healthy_tok_per_s", r.healthy_tok_per_s)
                .set("injected_tok_per_s", r.injected_tok_per_s)
                .set("mitigated_tok_per_s", r.mitigated_tok_per_s)
                .set("recovery", r.recovery.map(Json::Num).unwrap_or(Json::Null))
                .set("injected_token_skew", r.injected_token_skew)
                .set("mitigated_token_skew", r.mitigated_token_skew)
                .set("actions", r.actions),
        );
    }
    arr
}

impl DisaggReport {
    /// The deterministic `disagg` JSON section of `dpulens.fleet.v2`.
    pub fn to_json(&self) -> Json {
        let mut shapes = Json::arr();
        for s in disagg_shapes() {
            shapes.push(s.label());
        }
        Json::obj()
            .set("topology", shapes)
            .set("colocated_tok_per_s", self.colocated_tok_per_s)
            .set("colocated_ttft_p50_ns", self.colocated_ttft_p50_ns)
            .set("disagg_tok_per_s", self.disagg_tok_per_s)
            .set("disagg_ttft_p50_ns", self.disagg_ttft_p50_ns)
            .set("handoffs", self.handoffs)
            .set("handoff_bytes", self.handoff_bytes)
            .set("pd_conditions", condition_rows_json(&self.pd_rows))
    }

    /// Paper-style tables for the disaggregation study.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new("Phase disaggregation — colocated twin vs 2-pool topology")
            .header(&["topology", "tok/s", "ttft p50", "handoffs", "handoff MB"]);
        t.row(vec![
            "colocated 3x(tp4xpp2)".into(),
            format!("{:.0}", self.colocated_tok_per_s),
            fmt_ns(self.colocated_ttft_p50_ns),
            "0".into(),
            "0".into(),
        ]);
        t.row(vec![
            "prefill tp8 + 2x decode tp4xpp2".into(),
            format!("{:.0}", self.disagg_tok_per_s),
            fmt_ns(self.disagg_ttft_p50_ns),
            format!("{}", self.handoffs),
            format!("{:.1}", self.handoff_bytes as f64 / 1e6),
        ]);
        let mut out = t.render();
        let mut d = Table::new("PD condition family — inject, detect, mitigate (2-pool topology)")
            .header(&[
                "id", "detected", "latency", "healthy tok/s", "injected", "mitigated",
                "recovered", "actions",
            ]);
        for r in &self.pd_rows {
            d.row(vec![
                r.condition.id().to_string(),
                if r.detected { "yes".into() } else { "NO".into() },
                r.latency_ns.map(|n| fmt_ns(n as f64)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.healthy_tok_per_s),
                format!("{:.0}", r.injected_tok_per_s),
                format!("{:.0}", r.mitigated_tok_per_s),
                r.recovery.map(|f| format!("{:.0}%", f * 100.0)).unwrap_or_else(|| "-".into()),
                format!("{}", r.actions),
            ]);
        }
        out.push_str(&d.render());
        out
    }
}

impl MultiPoolReport {
    /// The deterministic `multipool` JSON section of `dpulens.fleet.v3`.
    pub fn to_json(&self) -> Json {
        let mut topo = Json::arr();
        for label in &self.topology {
            topo.push(label.as_str());
        }
        let pools_json = |pools: &[Vec<usize>]| {
            let mut arr = Json::arr();
            for p in pools {
                let mut inner = Json::arr();
                for &r in p {
                    inner.push(r as i64);
                }
                arr.push(inner);
            }
            arr
        };
        let mut pairs = Json::arr();
        for &(p, d, started, bytes) in &self.handoff_pairs {
            pairs.push(
                Json::obj()
                    .set("prefill_pool", p as i64)
                    .set("decode_pool", d as i64)
                    .set("handoffs", started)
                    .set("bytes", bytes),
            );
        }
        Json::obj()
            .set("replicas", self.replicas)
            .set("prefill_pool_count", self.prefill_pool_count)
            .set("decode_pool_count", self.decode_pool_count)
            .set("topology", topo)
            .set("prefill_pools", pools_json(&self.prefill_pools))
            .set("decode_pools", pools_json(&self.decode_pools))
            .set("healthy_tok_per_s", self.healthy_tok_per_s)
            .set("healthy_ttft_p50_ns", self.healthy_ttft_p50_ns)
            .set("handoffs", self.handoffs)
            .set("handoff_bytes", self.handoff_bytes)
            .set("handoff_pairs", pairs)
            .set("conditions", condition_rows_json(&self.rows))
            .set("skipped", {
                let mut arr = Json::arr();
                for c in &self.skipped {
                    arr.push(c.id());
                }
                arr
            })
    }

    /// Paper-style tables for the multi-pool study.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new(&format!(
            "Multi-pool fleet — {} replicas, {} prefill x {} decode pools",
            self.replicas, self.prefill_pool_count, self.decode_pool_count
        ))
        .header(&["section", "value"]);
        t.row(vec!["topology".into(), self.topology.join(", ")]);
        t.row(vec![
            "prefill pools".into(),
            format!("{:?}", self.prefill_pools),
        ]);
        t.row(vec!["decode pools".into(), format!("{:?}", self.decode_pools)]);
        t.row(vec![
            "healthy tok/s".into(),
            format!(
                "{:.0} (ttft p50 {})",
                self.healthy_tok_per_s,
                fmt_ns(self.healthy_ttft_p50_ns)
            ),
        ]);
        t.row(vec![
            "handoffs".into(),
            format!("{} ({:.1} MB)", self.handoffs, self.handoff_bytes as f64 / 1e6),
        ]);
        for &(p, d, n, bytes) in &self.handoff_pairs {
            t.row(vec![
                format!("pool pair P{p}->D{d}"),
                format!("{n} handoffs, {:.1} MB", bytes as f64 / 1e6),
            ]);
        }
        if !self.skipped.is_empty() {
            t.row(vec![
                "skipped (inert on topology)".into(),
                self.skipped.iter().map(|c| c.id()).collect::<Vec<_>>().join(", "),
            ]);
        }
        let mut out = t.render();
        let mut c =
            Table::new("Fleet conditions on the multi-pool topology — inject, detect, mitigate")
                .header(&[
                    "id", "detected", "latency", "healthy tok/s", "injected", "mitigated",
                    "recovered", "actions",
                ]);
        for r in &self.rows {
            c.row(vec![
                r.condition.id().to_string(),
                if r.detected { "yes".into() } else { "NO".into() },
                r.latency_ns.map(|n| fmt_ns(n as f64)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.healthy_tok_per_s),
                format!("{:.0}", r.injected_tok_per_s),
                format!("{:.0}", r.mitigated_tok_per_s),
                r.recovery.map(|f| format!("{:.0}%", f * 100.0)).unwrap_or_else(|| "-".into()),
                format!("{}", r.actions),
            ]);
        }
        out.push_str(&c.render());
        out
    }
}

impl TelemetryReport {
    /// The deterministic `telemetry` JSON section of `dpulens.fleet.v4`.
    pub fn to_json(&self) -> Json {
        let mut arr = Json::arr();
        for r in &self.rows {
            let mut ladder = Json::arr();
            for &(w, l) in &r.ladder_transitions {
                ladder.push(Json::obj().set("window", w).set("level", l as i64));
            }
            arr.push(
                Json::obj()
                    .set("id", r.condition.id())
                    .set("detected", r.detected)
                    .set(
                        "latency_ns",
                        r.latency_ns.map(|n| Json::Int(n as i64)).unwrap_or(Json::Null),
                    )
                    .set("healthy_tok_per_s", r.healthy_tok_per_s)
                    .set("injected_tok_per_s", r.injected_tok_per_s)
                    .set("mitigated_tok_per_s", r.mitigated_tok_per_s)
                    .set("throughput_held", r.throughput_held)
                    .set("ladder", ladder)
                    .set("max_ladder_level", r.max_ladder_level as i64)
                    .set("recovered_level", r.recovered_level as i64)
                    .set("fault_dropped", r.fault_dropped)
                    .set("fault_held", r.fault_held)
                    .set("actions", r.actions),
            );
        }
        Json::obj().set("td_conditions", arr)
    }

    /// Paper-style table for the degraded-telemetry study. The ladder
    /// column prints the injected cell's `level@window` transition path.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new(
            "TD condition family — degraded telemetry, fallback ladder (weighted baseline)",
        )
        .header(&[
            "id", "detected", "latency", "healthy tok/s", "injected", "mitigated", "held",
            "ladder", "recovered", "dropped/held", "actions",
        ]);
        for r in &self.rows {
            let ladder = if r.ladder_transitions.is_empty() {
                "-".into()
            } else {
                r.ladder_transitions
                    .iter()
                    .map(|&(w, l)| format!("{l}@w{w}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            t.row(vec![
                r.condition.id().to_string(),
                if r.detected { "yes".into() } else { "NO".into() },
                r.latency_ns.map(|n| fmt_ns(n as f64)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.healthy_tok_per_s),
                format!("{:.0}", r.injected_tok_per_s),
                format!("{:.0}", r.mitigated_tok_per_s),
                format!("{:.0}%", r.throughput_held * 100.0),
                ladder,
                format!("level {}", r.recovered_level),
                format!("{}/{}", r.fault_dropped, r.fault_held),
                format!("{}", r.actions),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_base_cfg_scales_the_cluster() {
        let cfg = fleet_base_cfg(4);
        assert_eq!(cfg.cluster.n_nodes, 8);
        assert_eq!(cfg.engine.nodes_per_stage, 1);
        assert_eq!(cfg.victim_replica, 3);
        cfg.cluster.validate().unwrap();
        let plans =
            crate::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage);
        assert_eq!(plans.len(), 4);
    }

    #[test]
    fn disagg_configs_shape_the_two_pool_topology() {
        let cfg = disagg_base_cfg();
        cfg.cluster.validate().unwrap();
        assert_eq!(cfg.cluster.n_nodes, 6);
        let shapes = cfg.engine.shapes.as_ref().unwrap();
        assert_eq!(shapes.len(), 3);
        assert_eq!(shapes[0].role, ReplicaRole::Prefill);
        assert_eq!(cfg.victim_replica, 2);
        let plans = crate::engine::build_shaped_replicas(&cfg.cluster, shapes);
        assert_eq!(plans.len(), 3);
        // The colocated twin shares nodes/profile/workload but no pools.
        let twin = colocated_twin_cfg();
        assert_eq!(twin.cluster.n_nodes, cfg.cluster.n_nodes);
        assert_eq!(twin.engine.profile.name, cfg.engine.profile.name);
        assert!(twin
            .engine
            .shapes
            .as_ref()
            .unwrap()
            .iter()
            .all(|s| s.role == ReplicaRole::Colocated));
    }

    #[test]
    fn disagg_cells_append_after_the_v1_sweep() {
        let mut fc = FleetConfig::new(2);
        assert_eq!(cells(&fc).len(), fc.policies.len() + 3 * DP_CONDITIONS.len());
        fc.disagg = true;
        let v = cells(&fc);
        assert_eq!(
            v.len(),
            fc.policies.len() + 3 * DP_CONDITIONS.len() + 2 + 3 * PD_CONDITIONS.len()
        );
        let base = fc.policies.len() + 3 * DP_CONDITIONS.len();
        assert_eq!(v[base], FleetCell::DisaggColocatedTwin);
        assert_eq!(v[base + 1], FleetCell::DisaggHealthy);
        assert_eq!(v[base + 2], FleetCell::PdHealthy(Condition::Pd1PrefillSaturation));
        // PD triples share shaping; only inject/mitigate differ.
        let healthy = cell_cfg(&fc, v[base + 2]);
        let inj = cell_cfg(&fc, v[base + 3]);
        let mit = cell_cfg(&fc, v[base + 4]);
        assert!(healthy.inject.is_none() && !healthy.mitigate);
        assert!(inj.inject.is_some() && !inj.mitigate);
        assert!(mit.inject.is_some() && mit.mitigate);
        assert_eq!(healthy.duration, inj.duration);
        // PD3's shaping presses on decode slots.
        let pd3 = cell_cfg(&fc, FleetCell::PdHealthy(Condition::Pd3DecodeStarvation));
        assert!(matches!(
            pd3.workload.output_len,
            crate::sim::dist::LengthDist::Uniform { lo: 24, .. }
        ));
        // The sweep's seed reaches every disagg cell (so --seed varies the
        // v2 section too, and base_seed in the JSON stays truthful).
        fc.base.seed = 777;
        for cell in disagg_cells() {
            assert_eq!(cell_cfg(&fc, cell).seed, 777, "{cell:?} ignored the sweep seed");
        }
    }

    #[test]
    fn multipool_cfg_shapes_an_arbitrary_topology() {
        let mp = MultiPoolSpec { replicas: 6, prefill_pools: 2, decode_pools: 1 };
        let cfg = multipool_base_cfg(&mp);
        cfg.cluster.validate().unwrap();
        assert_eq!(cfg.cluster.n_nodes, 6);
        let shapes = cfg.engine.shapes.as_ref().unwrap();
        assert_eq!(shapes.len(), 6);
        assert_eq!(shapes.iter().filter(|s| s.role == ReplicaRole::Prefill).count(), 2);
        assert_eq!(shapes.iter().filter(|s| s.role == ReplicaRole::Decode).count(), 4);
        assert_eq!(cfg.engine.prefill_pools, 2);
        assert_eq!(cfg.victim_replica, 5);
        let plans = crate::engine::build_shaped_replicas(&cfg.cluster, shapes);
        assert_eq!(plans.len(), 6);
        // Larger fleets scale the node budget one-to-one.
        let big = multipool_base_cfg(&MultiPoolSpec {
            replicas: 9,
            prefill_pools: 3,
            decode_pools: 2,
        });
        big.cluster.validate().unwrap();
        assert_eq!(big.cluster.n_nodes, 9);
        assert_eq!(big.engine.shapes.as_ref().unwrap().len(), 9);
    }

    #[test]
    fn multipool_cells_are_catalog_driven_triples() {
        let conds = fleet_conditions();
        assert_eq!(
            conds,
            DP_CONDITIONS.iter().chain(PD_CONDITIONS.iter()).copied().collect::<Vec<_>>()
        );
        let mp = MultiPoolSpec { replicas: 6, prefill_pools: 2, decode_pools: 1 };
        mp.validate().unwrap();
        // On 6/2x1 the prefill pools are singletons, so DP1's peer-skew
        // rule is structurally inert: skipped (reported), never simulated.
        let (run, skipped) = multipool_conditions(&mp);
        assert_eq!(skipped, vec![Condition::Dp1RouterFlowSkew]);
        assert_eq!(run.len(), 5);
        let v = multipool_cells(&mp);
        // Topology cell + 3 cells per self-shaping triple (DP3, PD3) + 2
        // per topology-shaped triple (DP2, PD1, PD2 reuse the topology
        // cell as their healthy reference).
        assert_eq!(v.len(), 1 + 3 * 2 + 2 * 3);
        assert_eq!(v[0], FleetCell::MpTopology);
        assert_eq!(v[1], FleetCell::MpInjected(Condition::Dp2HotReplicaKv));
        let mut fc = FleetConfig::new(6);
        fc.multipool = Some(mp);
        // DP2's would-be healthy cell IS the topology cell: identical
        // routing policy (affinity default) and workload.
        let topo = cell_cfg(&fc, FleetCell::MpTopology);
        let dp2h = cell_cfg(&fc, FleetCell::MpHealthy(Condition::Dp2HotReplicaKv));
        assert_eq!(topo.engine.route_policy, dp2h.engine.route_policy);
        assert_eq!(topo.duration, dp2h.duration);
        // Triples share shaping; only inject/mitigate differ — and the v3
        // block rides behind the v1 (+ optional v2) cells in the sweep.
        let all = cells(&fc);
        assert_eq!(all.len(), fc.policies.len() + 3 * DP_CONDITIONS.len() + v.len());
        let base = fc.policies.len() + 3 * DP_CONDITIONS.len();
        assert_eq!(all[base], FleetCell::MpTopology);
        let healthy = cell_cfg(&fc, FleetCell::MpHealthy(Condition::Dp3StragglerReplica));
        let inj = cell_cfg(&fc, FleetCell::MpInjected(Condition::Dp3StragglerReplica));
        let mit = cell_cfg(&fc, FleetCell::MpMitigated(Condition::Dp3StragglerReplica));
        assert!(healthy.inject.is_none() && !healthy.mitigate);
        assert!(inj.inject.is_some() && !inj.mitigate);
        assert!(mit.inject.is_some() && mit.mitigate);
        assert_eq!(healthy.duration, inj.duration);
        // DP cells ride the affinity baseline; catalog shaping scales DP3's
        // demand 2x over the topology cell.
        assert_eq!(inj.engine.route_policy, RoutePolicy::FlowHash);
        if let (
            crate::sim::dist::Arrival::Poisson { rate: topo_rate },
            crate::sim::dist::Arrival::Poisson { rate: dp3 },
        ) = (topo.workload.arrival, inj.workload.arrival)
        {
            assert!((dp3 - 2.0 * topo_rate).abs() < 1e-6, "{dp3} vs {topo_rate}");
        } else {
            panic!("multipool cells must use Poisson arrivals");
        }
        // A wider prefill tier (12 replicas: 4 prefill split into 2 pools
        // of 2) makes DP1's pools peer-capable: nothing skipped.
        let wide = MultiPoolSpec { replicas: 12, prefill_pools: 2, decode_pools: 2 };
        let (run, skipped) = multipool_conditions(&wide);
        assert!(skipped.is_empty(), "{skipped:?}");
        assert_eq!(run.len(), 6);
        // The sweep's seed reaches every multipool cell.
        fc.base.seed = 909;
        for cell in multipool_cells(&mp) {
            assert_eq!(cell_cfg(&fc, cell).seed, 909, "{cell:?} ignored the sweep seed");
        }
        // Invalid topologies are rejected before any cell runs.
        assert!(MultiPoolSpec { replicas: 4, prefill_pools: 1, decode_pools: 3 }
            .validate()
            .is_err());
        assert!(MultiPoolSpec { replicas: 2, prefill_pools: 2, decode_pools: 1 }
            .validate()
            .is_err());
    }

    #[test]
    fn td_cells_ride_last_on_the_weighted_baseline() {
        let mut fc = FleetConfig::new(2);
        let v1_len = cells(&fc).len();
        fc.telemetry_faults = true;
        let v = cells(&fc);
        // The TD block is appended LAST (after any disagg/multipool block),
        // so the v1-v3 cell prefix — and their JSON — never move.
        assert_eq!(v.len(), v1_len + 3 * TD_CONDITIONS.len());
        assert_eq!(v[v1_len], FleetCell::TdHealthy(Condition::Td1StaleFrozen));
        assert_eq!(v[v1_len + 1], FleetCell::TdInjected(Condition::Td1StaleFrozen));
        assert_eq!(v[v1_len + 2], FleetCell::TdMitigated(Condition::Td1StaleFrozen));
        fc.disagg = true;
        let with_disagg = cells(&fc);
        assert_eq!(
            with_disagg[with_disagg.len() - 3 * TD_CONDITIONS.len()],
            FleetCell::TdHealthy(Condition::Td1StaleFrozen)
        );
        // Triples share one shaped config on the telemetry-weighted policy
        // (the one the fallback ladder protects); only inject/mitigate
        // differ, and the sweep's seed reaches every cell.
        fc.base.seed = 4242;
        let healthy = cell_cfg(&fc, v[v1_len]);
        let inj = cell_cfg(&fc, v[v1_len + 1]);
        let mit = cell_cfg(&fc, v[v1_len + 2]);
        assert_eq!(healthy.engine.route_policy, RoutePolicy::WeightedTelemetry);
        assert!(healthy.inject.is_none() && !healthy.mitigate);
        assert!(inj.inject.is_some() && !inj.mitigate);
        assert!(mit.inject.is_some() && mit.mitigate);
        assert_eq!(healthy.duration, inj.duration);
        assert!(inj.duration > fc.base.duration);
        for cell in td_cells() {
            assert_eq!(cell_cfg(&fc, cell).seed, 4242, "{cell:?} ignored the sweep seed");
        }
    }

    #[test]
    fn cells_enumerate_policies_then_dp_triples() {
        let fc = FleetConfig::new(2);
        let v = cells(&fc);
        assert_eq!(v.len(), fc.policies.len() + 3 * DP_CONDITIONS.len());
        assert_eq!(v[0], FleetCell::Policy(RoutePolicy::FlowHash));
        let base_idx = fc.policies.len();
        assert_eq!(v[base_idx], FleetCell::DpHealthy(Condition::Dp1RouterFlowSkew));
        assert_eq!(v[base_idx + 1], FleetCell::DpInjected(Condition::Dp1RouterFlowSkew));
        assert_eq!(v[base_idx + 2], FleetCell::DpMitigated(Condition::Dp1RouterFlowSkew));
        // The triple shares one shaped config; only inject/mitigate differ.
        let healthy = cell_cfg(&fc, v[base_idx]);
        let inj = cell_cfg(&fc, v[base_idx + 1]);
        let mit = cell_cfg(&fc, v[base_idx + 2]);
        assert_eq!(inj.engine.route_policy, RoutePolicy::FlowHash);
        assert!(healthy.inject.is_none() && !healthy.mitigate);
        assert!(inj.inject.is_some() && !inj.mitigate);
        assert!(mit.inject.is_some() && mit.mitigate);
        assert_eq!(healthy.duration, inj.duration);
        assert_eq!(healthy.engine.profile.name, inj.engine.profile.name);
        assert!(inj.duration > fc.base.duration);
        // Saturation-sensitive DP cells promote the compute-dominated profile.
        assert_eq!(inj.engine.profile.name, "7b");
        let dp2 = cell_cfg(&fc, FleetCell::DpInjected(Condition::Dp2HotReplicaKv));
        assert_eq!(dp2.engine.profile.name, "small");
    }
}
