//! Fleet sweep: the replicas × routing-policy serving study plus the DP1-DP3
//! data-parallel condition experiments (inject → detect → mitigate) — the
//! engine behind `dpulens fleet`.
//!
//! A fleet world uses single-node pipeline stages so the default 4-GPU nodes
//! yield `2 × replicas` nodes and `replicas` data-parallel lanes. The sweep
//! runs, fanned out over `util::par` worker threads:
//!
//! * one healthy cell per routing policy (per-replica skew columns), and
//! * per DP condition, a healthy / injected / mitigated triple on the
//!   skew-prone affinity-hash baseline — all three on the same shaped
//!   config, so recovery is measured against a like-for-like reference.
//!
//! Aggregation order is fixed by the cell list, so the JSON form is
//! byte-identical across runs and `--threads` values.

use crate::cluster::{ReplicaRole, ReplicaShape};
use crate::coordinator::experiment::{inject_time, standard_cfg};
use crate::coordinator::scenario::{Scenario, ScenarioCfg};
use crate::dpu::detectors::{Condition, DP_CONDITIONS, PD_CONDITIONS};
use crate::engine::router::ALL_POLICIES;
use crate::engine::RoutePolicy;
use crate::sim::{SimDur, SimTime};
use crate::util::json::Json;
use crate::util::par::{parallel_map, resolve_threads};
use crate::util::table::{fmt_ns, Table};

/// Extra measurement time DP cells get past the standard duration, so the
/// post-mitigation phase is long enough for throughput to visibly recover.
const DP_EXTRA_MS: u64 = 1600;

/// Fleet-sweep configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Base scenario every cell derives from (already fleet-shaped).
    pub base: ScenarioCfg,
    pub replicas: usize,
    /// Routing policies swept for the healthy study.
    pub policies: Vec<RoutePolicy>,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Append the phase-disaggregation study (colocated-vs-disagg twin
    /// cells + the PD1-PD3 triples); bumps the JSON schema to v2.
    pub disagg: bool,
}

impl FleetConfig {
    pub fn new(replicas: usize) -> Self {
        FleetConfig {
            base: fleet_base_cfg(replicas),
            replicas,
            policies: ALL_POLICIES.to_vec(),
            threads: 0,
            disagg: false,
        }
    }
}

/// Base scenario for an `n`-replica fleet: single-node pipeline stages
/// (2 nodes per replica on the default spec), arrival scaled to the fleet,
/// and the victim replica set to the last (non-zero) lane.
pub fn fleet_base_cfg(replicas: usize) -> ScenarioCfg {
    assert!(replicas >= 1);
    let mut cfg = standard_cfg();
    cfg.cluster.n_nodes = 2 * replicas;
    cfg.cluster.pp_degree = 2;
    cfg.engine.nodes_per_stage = 1;
    cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 250.0 * replicas as f64 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 32 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    cfg.victim_replica = replicas.saturating_sub(1);
    cfg
}

/// The canonical two-pool topology of the disaggregation study: one TP8×PP1
/// prefill replica beside two TP4×PP2 decode replicas on six nodes.
pub fn disagg_shapes() -> Vec<ReplicaShape> {
    vec![
        ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
        ReplicaShape::new(ReplicaRole::Decode, 4, 2),
        ReplicaShape::new(ReplicaRole::Decode, 4, 2),
    ]
}

/// Base scenario for the phase-disaggregation study. The 7b cost profile
/// makes prefill genuinely compute-dominated (the phase asymmetry the
/// topology exists for); short prompts + short outputs keep the healthy
/// fleet comfortably inside both pools' capacity.
pub fn disagg_base_cfg() -> ScenarioCfg {
    let mut cfg = standard_cfg();
    cfg.cluster.n_nodes = 6;
    cfg.cluster.pp_degree = 2;
    cfg.engine.profile = crate::engine::preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.engine.shapes = Some(disagg_shapes());
    cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 500.0 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    // PD injections that resolve a victim node target the second decode
    // replica, mirroring the DP sweep's last-lane convention.
    cfg.victim_replica = 2;
    cfg.duration = cfg.duration + SimDur::from_ms(DP_EXTRA_MS);
    cfg
}

/// The colocated twin of [`disagg_base_cfg`]: same six nodes, same cost
/// profile and workload, but three TP4×PP2 colocated replicas — the
/// topology-comparison baseline.
pub fn colocated_twin_cfg() -> ScenarioCfg {
    let mut cfg = disagg_base_cfg();
    cfg.engine.shapes = Some(vec![ReplicaShape::new(ReplicaRole::Colocated, 4, 2); 3]);
    cfg
}

/// One cell of the fleet sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FleetCell {
    Policy(RoutePolicy),
    /// The DP condition's shaped config WITHOUT the injection — the
    /// like-for-like recovery baseline.
    DpHealthy(Condition),
    DpInjected(Condition),
    DpMitigated(Condition),
    /// Topology comparison: the colocated twin of the disagg base.
    DisaggColocatedTwin,
    /// Topology comparison: the healthy disaggregated base.
    DisaggHealthy,
    /// PD condition triples on the disaggregated base (same healthy /
    /// injected / mitigated discipline as the DP rows).
    PdHealthy(Condition),
    PdInjected(Condition),
    PdMitigated(Condition),
}

/// The shared shaping every cell of one DP condition's triple (healthy /
/// injected / mitigated) runs on, so their throughputs are comparable.
fn dp_shaped(fc: &FleetConfig, c: Condition) -> ScenarioCfg {
    let mut cfg = fc.base.clone();
    // DP conditions are studied on the skew-prone affinity baseline.
    cfg.engine.route_policy = RoutePolicy::FlowHash;
    cfg.duration = cfg.duration + SimDur::from_ms(DP_EXTRA_MS);
    match c {
        // Saturation-sensitive conditions need a compute-dominated cost
        // profile (cf. `shaped_cfg` for EW1): on the fast `small` model a
        // hot or slowed replica never runs out of capacity, so flow
        // concentration / degraded GPUs would not move throughput. The rate
        // scale keeps the hot/slow lane decisively past the 7b compute
        // bound while healthy lanes stay inside it.
        Condition::Dp1RouterFlowSkew => {
            cfg.engine.profile = crate::engine::preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            scale_rate(&mut cfg, 3.0);
        }
        Condition::Dp3StragglerReplica => {
            cfg.engine.profile = crate::engine::preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            scale_rate(&mut cfg, 2.0);
        }
        // DP2's KV leak is capacity-independent: the victim's pool starves
        // outright regardless of the cost profile.
        _ => {}
    }
    cfg
}

/// Per-condition shaping of the PD triples, applied on top of
/// [`disagg_base_cfg`] (the healthy cell shares the shaping, so recovery is
/// measured like for like).
fn pd_shaped(c: Condition) -> ScenarioCfg {
    let mut cfg = disagg_base_cfg();
    if c == Condition::Pd3DecodeStarvation {
        // Decode-slot pressure: the wedged replica must actually be the
        // constraint, so lengthen outputs and raise demand until the decode
        // pool runs near its slot capacity.
        cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 24, hi: 48 };
        scale_rate(&mut cfg, 2.0);
    }
    cfg
}

fn cell_cfg(fc: &FleetConfig, cell: FleetCell) -> ScenarioCfg {
    match cell {
        FleetCell::Policy(p) => {
            let mut cfg = fc.base.clone();
            cfg.engine.route_policy = p;
            cfg
        }
        FleetCell::DpHealthy(c) => dp_shaped(fc, c),
        FleetCell::DpInjected(c) | FleetCell::DpMitigated(c) => {
            let mut cfg = dp_shaped(fc, c);
            cfg.inject = Some((c, inject_time(&cfg)));
            cfg.mitigate = matches!(cell, FleetCell::DpMitigated(_));
            cfg
        }
        // The disagg study shapes its own topology/workload/duration, but
        // inherits the sweep's seed so `--seed` varies its replicates too
        // (and the report's base_seed stays truthful for the v2 section).
        FleetCell::DisaggColocatedTwin => {
            let mut cfg = colocated_twin_cfg();
            cfg.seed = fc.base.seed;
            cfg
        }
        FleetCell::DisaggHealthy => {
            let mut cfg = disagg_base_cfg();
            cfg.seed = fc.base.seed;
            cfg
        }
        FleetCell::PdHealthy(c) => {
            let mut cfg = pd_shaped(c);
            cfg.seed = fc.base.seed;
            cfg
        }
        FleetCell::PdInjected(c) | FleetCell::PdMitigated(c) => {
            let mut cfg = pd_shaped(c);
            cfg.seed = fc.base.seed;
            cfg.inject = Some((c, inject_time(&cfg)));
            cfg.mitigate = matches!(cell, FleetCell::PdMitigated(_));
            cfg
        }
    }
}

fn scale_rate(cfg: &mut ScenarioCfg, factor: f64) {
    if let crate::sim::dist::Arrival::Poisson { rate } = &cfg.workload.arrival {
        let scaled = rate * factor;
        cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: scaled };
    }
}

/// The disagg cell block, in the exact order `disagg_report_from` decodes:
/// topology twins first, then the PD triples. Shared by the full sweep and
/// the standalone study so the two cannot drift.
fn disagg_cells() -> Vec<FleetCell> {
    let mut v = vec![FleetCell::DisaggColocatedTwin, FleetCell::DisaggHealthy];
    for c in PD_CONDITIONS {
        v.push(FleetCell::PdHealthy(c));
        v.push(FleetCell::PdInjected(c));
        v.push(FleetCell::PdMitigated(c));
    }
    v
}

fn cells(fc: &FleetConfig) -> Vec<FleetCell> {
    let mut v: Vec<FleetCell> = fc.policies.iter().map(|&p| FleetCell::Policy(p)).collect();
    for c in DP_CONDITIONS {
        v.push(FleetCell::DpHealthy(c));
        v.push(FleetCell::DpInjected(c));
        v.push(FleetCell::DpMitigated(c));
    }
    if fc.disagg {
        v.extend(disagg_cells());
    }
    v
}

/// Compact per-cell result shipped back from a worker thread.
#[derive(Debug, Clone)]
struct CellOutcome {
    completed: u64,
    rejected: u64,
    tok_per_s: f64,
    req_per_s: f64,
    ttft_p50_ns: f64,
    ttft_p99_ns: f64,
    token_skew: f64,
    max_flow_share: f64,
    replica_tokens: Vec<u64>,
    kv_peak: Vec<f64>,
    detected: bool,
    latency_ns: Option<u64>,
    actions: u64,
    /// Telemetry events the cell's pipeline delivered (perf accounting).
    events: u64,
    /// KV handoffs completed / logical bytes delivered (zero when colocated).
    handoffs: u64,
    handoff_bytes: u64,
}

fn run_cell(fc: &FleetConfig, cell: FleetCell) -> CellOutcome {
    let cfg = cell_cfg(fc, cell);
    let res = Scenario::new(cfg).run();
    let injected = match cell {
        FleetCell::DpInjected(c)
        | FleetCell::DpMitigated(c)
        | FleetCell::PdInjected(c)
        | FleetCell::PdMitigated(c) => Some(c),
        _ => None,
    };
    let t0 = res.injected_at.unwrap_or(SimTime(u64::MAX));
    let detected = injected
        .map(|c| res.detections.iter().any(|d| d.condition == c && d.at >= t0))
        .unwrap_or(false);
    let latency_ns = injected.and_then(|c| res.detection_latency(c)).map(|d| d.ns());
    let total_routed: u64 = res.replica_routed.iter().sum();
    let max_flow_share = if total_routed == 0 {
        0.0
    } else {
        *res.replica_routed.iter().max().unwrap() as f64 / total_routed as f64
    };
    CellOutcome {
        completed: res.metrics.completed,
        rejected: res.metrics.rejected,
        tok_per_s: res.metrics.tok_per_s(),
        req_per_s: res.metrics.req_per_s(),
        ttft_p50_ns: res.metrics.ttft_ns.p50(),
        ttft_p99_ns: res.metrics.ttft_ns.p99(),
        token_skew: res.metrics.replica_token_skew(),
        max_flow_share,
        replica_tokens: res.metrics.per_replica.iter().map(|l| l.tokens_out).collect(),
        kv_peak: res.replica_kv_peak,
        detected,
        latency_ns,
        actions: res.actions.len() as u64,
        events: res.telemetry_published,
        handoffs: res.handoffs.completed,
        handoff_bytes: res.handoffs.bytes_delivered,
    }
}

/// One healthy routing-policy row.
#[derive(Debug, Clone)]
pub struct PolicyRow {
    pub policy: RoutePolicy,
    pub completed: u64,
    pub rejected: u64,
    pub req_per_s: f64,
    pub tok_per_s: f64,
    pub ttft_p50_ns: f64,
    pub ttft_p99_ns: f64,
    /// Max-over-mean token share across replicas (1.0 = balanced).
    pub token_skew: f64,
    /// Largest per-replica share of routed arrivals.
    pub max_flow_share: f64,
    pub replica_tokens: Vec<u64>,
    pub kv_peak: Vec<f64>,
}

/// One DP condition's inject → detect → mitigate row.
#[derive(Debug, Clone)]
pub struct DpRow {
    pub condition: Condition,
    pub detected: bool,
    pub latency_ns: Option<u64>,
    pub healthy_tok_per_s: f64,
    pub injected_tok_per_s: f64,
    pub mitigated_tok_per_s: f64,
    /// Fraction of lost throughput the closed loop recovered, measured
    /// against the same shaped config WITHOUT the injection (clamped to
    /// 0..1.5). For conditions whose injection itself raises demand (DP1's
    /// flash crowd), the baseline reflects pre-surge demand, so the value
    /// saturates high once the mitigated fleet outserves it.
    pub recovery: Option<f64>,
    pub injected_token_skew: f64,
    pub mitigated_token_skew: f64,
    /// Mitigation actions taken in the mitigated run.
    pub actions: u64,
}

/// The phase-disaggregation study: the colocated-vs-disagg topology twins
/// plus the PD1-PD3 inject → detect → mitigate triples.
#[derive(Debug)]
pub struct DisaggReport {
    /// Healthy throughput/latency of the colocated twin topology.
    pub colocated_tok_per_s: f64,
    pub colocated_ttft_p50_ns: f64,
    /// Healthy throughput/latency of the disaggregated base topology.
    pub disagg_tok_per_s: f64,
    pub disagg_ttft_p50_ns: f64,
    /// Healthy disagg cell's KV-handoff volume (completed / logical bytes).
    pub handoffs: u64,
    pub handoff_bytes: u64,
    /// PD condition rows (same shape/discipline as the DP rows).
    pub pd_rows: Vec<DpRow>,
}

/// Everything a fleet sweep produces.
#[derive(Debug)]
pub struct FleetReport {
    pub replicas: usize,
    pub base_seed: u64,
    pub policy_rows: Vec<PolicyRow>,
    pub dp_rows: Vec<DpRow>,
    /// The phase-disaggregation section (`--disagg`; bumps JSON to v2).
    pub disagg: Option<DisaggReport>,
    pub cells_run: usize,
    pub threads_used: usize,
    /// Wall-clock of the parallel cell sweep, ms. Perf metadata: reported
    /// in the human output and `dpulens perf`, excluded from `to_json` so
    /// the fleet JSON stays byte-identical across thread counts.
    pub elapsed_ms: f64,
    /// Telemetry events delivered across all cells' pipelines.
    pub events_total: u64,
}

impl FleetReport {
    /// Pipeline ingest throughput of the whole sweep (events/sec).
    pub fn events_per_sec(&self) -> f64 {
        crate::util::perf::events_per_sec(self.events_total, self.elapsed_ms)
    }
}

/// Execute the fleet sweep in parallel and aggregate in cell order.
/// Wall-clock and events/sec land in the report's perf fields (excluded
/// from the deterministic JSON; see `FleetReport::to_json`).
pub fn run_fleet(fc: &FleetConfig) -> FleetReport {
    let cell_list = cells(fc);
    let threads_used = resolve_threads(fc.threads, cell_list.len());
    let timer = crate::util::perf::PhaseTimer::start();
    let mut outcomes = parallel_map(&cell_list, fc.threads, |&cell| run_cell(fc, cell));
    let elapsed_ms = timer.total_ms();
    let events_total: u64 = outcomes.iter().map(|o| o.events).sum();

    let n_pol = fc.policies.len();
    // The DP triples only need scalar outcomes; the policy rows take the
    // per-replica vectors by move (no re-clone of worker results).
    let mut dp_outcomes = outcomes.split_off(n_pol);
    let disagg_outcomes = dp_outcomes.split_off(3 * DP_CONDITIONS.len());
    let policy_rows: Vec<PolicyRow> = fc
        .policies
        .iter()
        .zip(outcomes)
        .map(|(&policy, o)| PolicyRow {
            policy,
            completed: o.completed,
            rejected: o.rejected,
            req_per_s: o.req_per_s,
            tok_per_s: o.tok_per_s,
            ttft_p50_ns: o.ttft_p50_ns,
            ttft_p99_ns: o.ttft_p99_ns,
            token_skew: o.token_skew,
            max_flow_share: o.max_flow_share,
            replica_tokens: o.replica_tokens,
            kv_peak: o.kv_peak,
        })
        .collect();

    let dp_rows = condition_rows(&dp_outcomes, &DP_CONDITIONS);
    let disagg = if fc.disagg { Some(disagg_report_from(&disagg_outcomes)) } else { None };

    FleetReport {
        replicas: fc.replicas,
        base_seed: fc.base.seed,
        policy_rows,
        dp_rows,
        disagg,
        cells_run: cell_list.len(),
        threads_used,
        elapsed_ms,
        events_total,
    }
}

/// Fold healthy/injected/mitigated triples into condition rows. Each triple
/// runs the SAME shaped config, so the healthy cell is a like-for-like
/// recovery baseline.
fn condition_rows(outcomes: &[CellOutcome], conds: &[Condition]) -> Vec<DpRow> {
    assert_eq!(outcomes.len(), 3 * conds.len());
    let mut rows = Vec::with_capacity(conds.len());
    for (k, &c) in conds.iter().enumerate() {
        let healthy = &outcomes[3 * k];
        let inj = &outcomes[3 * k + 1];
        let mit = &outcomes[3 * k + 2];
        let recovery = if healthy.tok_per_s - inj.tok_per_s < 1e-9 {
            Some(1.0)
        } else {
            Some(
                ((mit.tok_per_s - inj.tok_per_s) / (healthy.tok_per_s - inj.tok_per_s))
                    .clamp(0.0, 1.5),
            )
        };
        rows.push(DpRow {
            condition: c,
            detected: inj.detected,
            latency_ns: inj.latency_ns,
            healthy_tok_per_s: healthy.tok_per_s,
            injected_tok_per_s: inj.tok_per_s,
            mitigated_tok_per_s: mit.tok_per_s,
            recovery,
            injected_token_skew: inj.token_skew,
            mitigated_token_skew: mit.token_skew,
            actions: mit.actions,
        });
    }
    rows
}

/// Aggregate the disagg block (twin, healthy, then the PD triples) into a
/// [`DisaggReport`].
fn disagg_report_from(outcomes: &[CellOutcome]) -> DisaggReport {
    assert_eq!(outcomes.len(), 2 + 3 * PD_CONDITIONS.len());
    let twin = &outcomes[0];
    let healthy = &outcomes[1];
    DisaggReport {
        colocated_tok_per_s: twin.tok_per_s,
        colocated_ttft_p50_ns: twin.ttft_p50_ns,
        disagg_tok_per_s: healthy.tok_per_s,
        disagg_ttft_p50_ns: healthy.ttft_p50_ns,
        handoffs: healthy.handoffs,
        handoff_bytes: healthy.handoff_bytes,
        pd_rows: condition_rows(&outcomes[2..], &PD_CONDITIONS),
    }
}

/// Run only the phase-disaggregation study (the `--disagg` block without
/// the v1 policy/DP cells) — the disagg acceptance suite's entrypoint.
/// Uses the default sweep seed; disagg cells only take the seed from the
/// FleetConfig, so the rest of it is irrelevant here.
pub fn run_disagg_study(threads: usize) -> DisaggReport {
    let fc = FleetConfig::new(2);
    let cell_list = disagg_cells();
    let outcomes = parallel_map(&cell_list, threads, |&cell| run_cell(&fc, cell));
    disagg_report_from(&outcomes)
}

impl FleetReport {
    /// Paper-style tables: the policy study and the DP condition study.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new(&format!(
            "Fleet study — {} replicas × routing policies (healthy)",
            self.replicas
        ))
        .header(&[
            "policy", "done", "rej", "req/s", "tok/s", "ttft p50", "ttft p99", "tok skew",
            "max share", "kv peak",
        ]);
        for r in &self.policy_rows {
            let kv_peak = r.kv_peak.iter().cloned().fold(0.0_f64, f64::max);
            t.row(vec![
                r.policy.id().to_string(),
                format!("{}", r.completed),
                format!("{}", r.rejected),
                format!("{:.1}", r.req_per_s),
                format!("{:.0}", r.tok_per_s),
                fmt_ns(r.ttft_p50_ns),
                fmt_ns(r.ttft_p99_ns),
                format!("{:.2}", r.token_skew),
                format!("{:.2}", r.max_flow_share),
                format!("{:.2}", kv_peak),
            ]);
        }
        let mut out = t.render();
        let mut d = Table::new("DP condition family — inject, detect, mitigate (affinity baseline)")
            .header(&[
                "id", "detected", "latency", "healthy tok/s", "injected", "mitigated",
                "recovered", "skew inj->mit", "actions",
            ]);
        for r in &self.dp_rows {
            d.row(vec![
                r.condition.id().to_string(),
                if r.detected { "yes".into() } else { "NO".into() },
                r.latency_ns.map(|n| fmt_ns(n as f64)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.healthy_tok_per_s),
                format!("{:.0}", r.injected_tok_per_s),
                format!("{:.0}", r.mitigated_tok_per_s),
                r.recovery.map(|f| format!("{:.0}%", f * 100.0)).unwrap_or_else(|| "-".into()),
                format!("{:.2} -> {:.2}", r.injected_token_skew, r.mitigated_token_skew),
                format!("{}", r.actions),
            ]);
        }
        out.push_str(&d.render());
        if let Some(disagg) = &self.disagg {
            out.push_str(&disagg.render_tables());
        }
        out
    }

    /// One-paragraph human summary.
    pub fn summary_line(&self) -> String {
        let best = self
            .policy_rows
            .iter()
            .max_by(|a, b| a.tok_per_s.partial_cmp(&b.tok_per_s).unwrap());
        let detected = self.dp_rows.iter().filter(|r| r.detected).count();
        let mut s = format!(
            "fleet of {} replicas: DP conditions detected {}/{}",
            self.replicas,
            detected,
            self.dp_rows.len()
        );
        if let Some(d) = &self.disagg {
            let pd = d.pd_rows.iter().filter(|r| r.detected).count();
            s.push_str(&format!(
                "; PD conditions detected {pd}/{} on the 2-pool topology ({} handoffs)",
                d.pd_rows.len(),
                d.handoffs
            ));
        }
        if let Some(b) = best {
            s.push_str(&format!(
                "; best healthy policy {} at {:.0} tok/s (token skew {:.2})",
                b.policy.id(),
                b.tok_per_s,
                b.token_skew
            ));
        }
        s
    }

    /// Deterministic JSON: same config + seed ⇒ byte-identical output,
    /// independent of worker-thread count (wallclock/threads excluded).
    /// Without `--disagg` this is schema v1, byte-identical to the pre-PD
    /// output; the disagg section bumps it to `dpulens.fleet.v2`.
    pub fn to_json(&self) -> Json {
        let mut policies = Json::arr();
        for r in &self.policy_rows {
            let mut tokens = Json::arr();
            for &t in &r.replica_tokens {
                tokens.push(t);
            }
            let mut peaks = Json::arr();
            for &p in &r.kv_peak {
                peaks.push(p);
            }
            policies.push(
                Json::obj()
                    .set("policy", r.policy.id())
                    .set("completed", r.completed)
                    .set("rejected", r.rejected)
                    .set("req_per_s", r.req_per_s)
                    .set("tok_per_s", r.tok_per_s)
                    .set("ttft_p50_ns", r.ttft_p50_ns)
                    .set("ttft_p99_ns", r.ttft_p99_ns)
                    .set("replica_token_skew", r.token_skew)
                    .set("max_flow_share", r.max_flow_share)
                    .set("replica_tokens", tokens)
                    .set("replica_kv_peak", peaks),
            );
        }
        let dp = condition_rows_json(&self.dp_rows);
        let schema = if self.disagg.is_some() { "dpulens.fleet.v2" } else { "dpulens.fleet.v1" };
        let mut out = Json::obj()
            .set("schema", schema)
            .set("replicas", self.replicas)
            .set("base_seed", self.base_seed)
            .set("policies", policies)
            .set("dp_conditions", dp);
        if let Some(d) = &self.disagg {
            out = out.set("disagg", d.to_json());
        }
        out
    }
}

fn condition_rows_json(rows: &[DpRow]) -> Json {
    let mut arr = Json::arr();
    for r in rows {
        arr.push(
            Json::obj()
                .set("id", r.condition.id())
                .set("detected", r.detected)
                .set(
                    "latency_ns",
                    r.latency_ns.map(|n| Json::Int(n as i64)).unwrap_or(Json::Null),
                )
                .set("healthy_tok_per_s", r.healthy_tok_per_s)
                .set("injected_tok_per_s", r.injected_tok_per_s)
                .set("mitigated_tok_per_s", r.mitigated_tok_per_s)
                .set("recovery", r.recovery.map(Json::Num).unwrap_or(Json::Null))
                .set("injected_token_skew", r.injected_token_skew)
                .set("mitigated_token_skew", r.mitigated_token_skew)
                .set("actions", r.actions),
        );
    }
    arr
}

impl DisaggReport {
    /// The deterministic `disagg` JSON section of `dpulens.fleet.v2`.
    pub fn to_json(&self) -> Json {
        let mut shapes = Json::arr();
        for s in disagg_shapes() {
            shapes.push(s.label());
        }
        Json::obj()
            .set("topology", shapes)
            .set("colocated_tok_per_s", self.colocated_tok_per_s)
            .set("colocated_ttft_p50_ns", self.colocated_ttft_p50_ns)
            .set("disagg_tok_per_s", self.disagg_tok_per_s)
            .set("disagg_ttft_p50_ns", self.disagg_ttft_p50_ns)
            .set("handoffs", self.handoffs)
            .set("handoff_bytes", self.handoff_bytes)
            .set("pd_conditions", condition_rows_json(&self.pd_rows))
    }

    /// Paper-style tables for the disaggregation study.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new("Phase disaggregation — colocated twin vs 2-pool topology")
            .header(&["topology", "tok/s", "ttft p50", "handoffs", "handoff MB"]);
        t.row(vec![
            "colocated 3x(tp4xpp2)".into(),
            format!("{:.0}", self.colocated_tok_per_s),
            fmt_ns(self.colocated_ttft_p50_ns),
            "0".into(),
            "0".into(),
        ]);
        t.row(vec![
            "prefill tp8 + 2x decode tp4xpp2".into(),
            format!("{:.0}", self.disagg_tok_per_s),
            fmt_ns(self.disagg_ttft_p50_ns),
            format!("{}", self.handoffs),
            format!("{:.1}", self.handoff_bytes as f64 / 1e6),
        ]);
        let mut out = t.render();
        let mut d = Table::new("PD condition family — inject, detect, mitigate (2-pool topology)")
            .header(&[
                "id", "detected", "latency", "healthy tok/s", "injected", "mitigated",
                "recovered", "actions",
            ]);
        for r in &self.pd_rows {
            d.row(vec![
                r.condition.id().to_string(),
                if r.detected { "yes".into() } else { "NO".into() },
                r.latency_ns.map(|n| fmt_ns(n as f64)).unwrap_or_else(|| "-".into()),
                format!("{:.0}", r.healthy_tok_per_s),
                format!("{:.0}", r.injected_tok_per_s),
                format!("{:.0}", r.mitigated_tok_per_s),
                r.recovery.map(|f| format!("{:.0}%", f * 100.0)).unwrap_or_else(|| "-".into()),
                format!("{}", r.actions),
            ]);
        }
        out.push_str(&d.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_base_cfg_scales_the_cluster() {
        let cfg = fleet_base_cfg(4);
        assert_eq!(cfg.cluster.n_nodes, 8);
        assert_eq!(cfg.engine.nodes_per_stage, 1);
        assert_eq!(cfg.victim_replica, 3);
        cfg.cluster.validate().unwrap();
        let plans =
            crate::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage);
        assert_eq!(plans.len(), 4);
    }

    #[test]
    fn disagg_configs_shape_the_two_pool_topology() {
        let cfg = disagg_base_cfg();
        cfg.cluster.validate().unwrap();
        assert_eq!(cfg.cluster.n_nodes, 6);
        let shapes = cfg.engine.shapes.as_ref().unwrap();
        assert_eq!(shapes.len(), 3);
        assert_eq!(shapes[0].role, ReplicaRole::Prefill);
        assert_eq!(cfg.victim_replica, 2);
        let plans = crate::engine::build_shaped_replicas(&cfg.cluster, shapes);
        assert_eq!(plans.len(), 3);
        // The colocated twin shares nodes/profile/workload but no pools.
        let twin = colocated_twin_cfg();
        assert_eq!(twin.cluster.n_nodes, cfg.cluster.n_nodes);
        assert_eq!(twin.engine.profile.name, cfg.engine.profile.name);
        assert!(twin
            .engine
            .shapes
            .as_ref()
            .unwrap()
            .iter()
            .all(|s| s.role == ReplicaRole::Colocated));
    }

    #[test]
    fn disagg_cells_append_after_the_v1_sweep() {
        let mut fc = FleetConfig::new(2);
        assert_eq!(cells(&fc).len(), fc.policies.len() + 3 * DP_CONDITIONS.len());
        fc.disagg = true;
        let v = cells(&fc);
        assert_eq!(
            v.len(),
            fc.policies.len() + 3 * DP_CONDITIONS.len() + 2 + 3 * PD_CONDITIONS.len()
        );
        let base = fc.policies.len() + 3 * DP_CONDITIONS.len();
        assert_eq!(v[base], FleetCell::DisaggColocatedTwin);
        assert_eq!(v[base + 1], FleetCell::DisaggHealthy);
        assert_eq!(v[base + 2], FleetCell::PdHealthy(Condition::Pd1PrefillSaturation));
        // PD triples share shaping; only inject/mitigate differ.
        let healthy = cell_cfg(&fc, v[base + 2]);
        let inj = cell_cfg(&fc, v[base + 3]);
        let mit = cell_cfg(&fc, v[base + 4]);
        assert!(healthy.inject.is_none() && !healthy.mitigate);
        assert!(inj.inject.is_some() && !inj.mitigate);
        assert!(mit.inject.is_some() && mit.mitigate);
        assert_eq!(healthy.duration, inj.duration);
        // PD3's shaping presses on decode slots.
        let pd3 = cell_cfg(&fc, FleetCell::PdHealthy(Condition::Pd3DecodeStarvation));
        assert!(matches!(
            pd3.workload.output_len,
            crate::sim::dist::LengthDist::Uniform { lo: 24, .. }
        ));
        // The sweep's seed reaches every disagg cell (so --seed varies the
        // v2 section too, and base_seed in the JSON stays truthful).
        fc.base.seed = 777;
        for cell in disagg_cells() {
            assert_eq!(cell_cfg(&fc, cell).seed, 777, "{cell:?} ignored the sweep seed");
        }
    }

    #[test]
    fn cells_enumerate_policies_then_dp_triples() {
        let fc = FleetConfig::new(2);
        let v = cells(&fc);
        assert_eq!(v.len(), fc.policies.len() + 3 * DP_CONDITIONS.len());
        assert_eq!(v[0], FleetCell::Policy(RoutePolicy::FlowHash));
        let base_idx = fc.policies.len();
        assert_eq!(v[base_idx], FleetCell::DpHealthy(Condition::Dp1RouterFlowSkew));
        assert_eq!(v[base_idx + 1], FleetCell::DpInjected(Condition::Dp1RouterFlowSkew));
        assert_eq!(v[base_idx + 2], FleetCell::DpMitigated(Condition::Dp1RouterFlowSkew));
        // The triple shares one shaped config; only inject/mitigate differ.
        let healthy = cell_cfg(&fc, v[base_idx]);
        let inj = cell_cfg(&fc, v[base_idx + 1]);
        let mit = cell_cfg(&fc, v[base_idx + 2]);
        assert_eq!(inj.engine.route_policy, RoutePolicy::FlowHash);
        assert!(healthy.inject.is_none() && !healthy.mitigate);
        assert!(inj.inject.is_some() && !inj.mitigate);
        assert!(mit.inject.is_some() && mit.mitigate);
        assert_eq!(healthy.duration, inj.duration);
        assert_eq!(healthy.engine.profile.name, inj.engine.profile.name);
        assert!(inj.duration > fc.base.duration);
        // Saturation-sensitive DP cells promote the compute-dominated profile.
        assert_eq!(inj.engine.profile.name, "7b");
        let dp2 = cell_cfg(&fc, FleetCell::DpInjected(Condition::Dp2HotReplicaKv));
        assert_eq!(dp2.engine.profile.name, "small");
    }
}
