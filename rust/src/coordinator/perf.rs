//! The `dpulens perf` pipeline benchmark — the measured baseline for the
//! telemetry hot path (see EXPERIMENTS.md §Perf).
//!
//! Four phases, each timed with [`crate::util::perf::PhaseTimer`]:
//!
//! 1. **ingest** — raw batched throughput of the bus → agent → window path:
//!    a synthetic, deterministic event mix streamed through one node's DPU
//!    agent in slices, reported as events/sec;
//! 2. **snapshot** — `WindowAccum::snapshot` latency under a realistic flow
//!    population (p50/max µs over many windows);
//! 3. **matrix** — `run_matrix` end-to-end wall-clock and pipeline events/sec;
//! 4. **fleet** — `run_fleet` end-to-end wall-clock and pipeline events/sec.
//!
//! The JSON form (`BENCH_pipeline.json`, schema `dpulens.perf.v1`) has a
//! deterministic *shape* — fixed keys, deterministic event counts — while
//! the timing values vary by machine; CI uploads it per PR so the bench
//! trajectory accumulates.

use crate::coordinator::fleet::{run_fleet, FleetConfig};
use crate::coordinator::matrix::{run_matrix, MatrixConfig};
use crate::dpu::agent::DpuPlane;
use crate::dpu::detectors::DetectConfig;
use crate::ids::{FlowId, GpuId, NodeId, QpId, ReqId, StageId};
use crate::sim::SimTime;
use crate::telemetry::event::{Phase, TelemetryEvent, TelemetryKind};
use crate::telemetry::window::WindowAccum;
use crate::util::json::Json;
use crate::util::perf::{events_per_sec, PhaseTimer};
use crate::util::stats::Summary;

/// Perf-harness configuration.
#[derive(Debug, Clone)]
pub struct PerfConfig {
    /// Synthetic events streamed through the ingest microbench.
    pub ingest_events: usize,
    /// Slice size per batched `DpuPlane::ingest` call.
    pub ingest_batch: usize,
    /// Windows measured in the snapshot-latency microbench.
    pub snapshot_windows: usize,
    /// Events accumulated per measured window.
    pub snapshot_events_per_window: usize,
    /// Seed replicates for the matrix end-to-end phase.
    pub matrix_replicates: usize,
    /// Replica count for the fleet end-to-end phase.
    pub fleet_replicas: usize,
    /// Worker threads for the end-to-end phases; 0 = one per core.
    pub threads: usize,
    /// Skip the (multi-second) matrix/fleet end-to-end phases.
    pub micro_only: bool,
    /// Label recorded in the JSON (`--quick` vs full).
    pub quick: bool,
}

impl PerfConfig {
    /// CI-friendly sizing: small microbenches, one matrix replicate, a
    /// 2-replica fleet.
    pub fn quick() -> Self {
        PerfConfig {
            ingest_events: 200_000,
            ingest_batch: 1024,
            snapshot_windows: 64,
            snapshot_events_per_window: 2_000,
            matrix_replicates: 1,
            fleet_replicas: 2,
            threads: 0,
            micro_only: false,
            quick: true,
        }
    }

    /// The full baseline: the acceptance configuration (`matrix
    /// --replicates 3`, 4-replica fleet) plus larger microbenches.
    pub fn full() -> Self {
        PerfConfig {
            ingest_events: 2_000_000,
            ingest_batch: 1024,
            snapshot_windows: 200,
            snapshot_events_per_window: 4_000,
            matrix_replicates: 3,
            fleet_replicas: 4,
            threads: 0,
            micro_only: false,
            quick: false,
        }
    }
}

/// Everything one perf run measures.
#[derive(Debug)]
pub struct PerfReport {
    pub quick: bool,
    pub ingest_events: u64,
    pub ingest_ms: f64,
    pub snapshot_windows: u64,
    pub snapshot_p50_us: f64,
    pub snapshot_max_us: f64,
    pub matrix_cells: u64,
    pub matrix_replicates: u64,
    pub matrix_threads: u64,
    pub matrix_ms: f64,
    pub matrix_events: u64,
    pub matrix_detected: u64,
    pub fleet_cells: u64,
    pub fleet_replicas: u64,
    pub fleet_threads: u64,
    pub fleet_ms: f64,
    pub fleet_events: u64,
}

impl PerfReport {
    pub fn ingest_events_per_sec(&self) -> f64 {
        events_per_sec(self.ingest_events, self.ingest_ms)
    }

    pub fn matrix_events_per_sec(&self) -> f64 {
        events_per_sec(self.matrix_events, self.matrix_ms)
    }

    pub fn fleet_events_per_sec(&self) -> f64 {
        events_per_sec(self.fleet_events, self.fleet_ms)
    }

    /// `dpulens.perf.v1`: fixed key shape; timing values machine-dependent.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("schema", "dpulens.perf.v1")
            .set("quick", self.quick)
            .set(
                "ingest",
                Json::obj()
                    .set("events", self.ingest_events)
                    .set("elapsed_ms", self.ingest_ms)
                    .set("events_per_sec", self.ingest_events_per_sec()),
            )
            .set(
                "snapshot",
                Json::obj()
                    .set("windows", self.snapshot_windows)
                    .set("p50_us", self.snapshot_p50_us)
                    .set("max_us", self.snapshot_max_us),
            )
            .set(
                "matrix",
                Json::obj()
                    .set("cells", self.matrix_cells)
                    .set("replicates", self.matrix_replicates)
                    .set("threads", self.matrix_threads)
                    .set("elapsed_ms", self.matrix_ms)
                    .set("events", self.matrix_events)
                    .set("events_per_sec", self.matrix_events_per_sec())
                    .set("detected", self.matrix_detected),
            )
            .set(
                "fleet",
                Json::obj()
                    .set("cells", self.fleet_cells)
                    .set("replicas", self.fleet_replicas)
                    .set("threads", self.fleet_threads)
                    .set("elapsed_ms", self.fleet_ms)
                    .set("events", self.fleet_events)
                    .set("events_per_sec", self.fleet_events_per_sec()),
            )
    }

    /// Human-readable summary lines.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "ingest:   {} events in {:.1} ms ({:.0} events/s)\n",
            self.ingest_events,
            self.ingest_ms,
            self.ingest_events_per_sec()
        ));
        s.push_str(&format!(
            "snapshot: {} windows, p50 {:.1} us, max {:.1} us\n",
            self.snapshot_windows, self.snapshot_p50_us, self.snapshot_max_us
        ));
        if self.matrix_cells > 0 {
            s.push_str(&format!(
                "matrix:   {} cells ({} replicates) in {:.1} ms on {} threads \
                 ({} events, {:.0} events/s), {} conditions detected\n",
                self.matrix_cells,
                self.matrix_replicates,
                self.matrix_ms,
                self.matrix_threads,
                self.matrix_events,
                self.matrix_events_per_sec(),
                self.matrix_detected
            ));
        }
        if self.fleet_cells > 0 {
            s.push_str(&format!(
                "fleet:    {} cells ({} replicas) in {:.1} ms on {} threads \
                 ({} events, {:.0} events/s)\n",
                self.fleet_cells,
                self.fleet_replicas,
                self.fleet_ms,
                self.fleet_threads,
                self.fleet_events,
                self.fleet_events_per_sec()
            ));
        }
        s
    }
}

/// Deterministic synthetic event mix: every DPU-relevant vantage plus the
/// invisible classes (so the visibility filter is part of the measured
/// path). Node 0; timestamps advance 1 µs per event.
fn synth_event(i: usize) -> TelemetryEvent {
    let t = SimTime(1_000 * (i as u64 + 1));
    let kind = match i % 8 {
        0 => TelemetryKind::DmaH2d {
            gpu: GpuId((i % 4) as u32),
            bytes: 4096,
            latency_ns: 500 + (i % 7) as u64 * 100,
            phase: if i % 2 == 0 { Phase::Prefill } else { Phase::Decode },
        },
        1 => TelemetryKind::Doorbell { gpu: GpuId((i % 4) as u32) },
        2 => TelemetryKind::NicRx {
            flow: FlowId((i % 64) as u32),
            bytes: 1500,
            queue_depth: (i % 16) as u32,
        },
        3 => TelemetryKind::NicTx {
            flow: FlowId((i % 64) as u32),
            bytes: 128,
            queue_depth: (i % 16) as u32,
            wait_ns: (i % 1000) as u64,
        },
        4 => TelemetryKind::RdmaOp {
            qp: QpId((i % 16) as u32),
            bytes: 65_536,
            credit_wait_ns: (i % 100) as u64,
            latency_ns: 2_000,
        },
        5 => TelemetryKind::StageHandoff {
            from_stage: StageId(0),
            to_stage: StageId(1),
            bytes: 32_768,
            outbound: false,
            phase: Phase::Decode,
        },
        6 => TelemetryKind::PcieUtil {
            link: crate::ids::LinkId(0),
            busy: (i % 100) as f64 / 100.0,
        },
        _ => TelemetryKind::NvlinkBurst { from: GpuId(0), to: GpuId(1), bytes: 1 << 20 },
    };
    TelemetryEvent { t, node: NodeId(0), kind }
}

/// Phase 1: batched ingest throughput through a one-node DPU plane. Only
/// the ingest/window-tick calls are timed — synthetic event generation
/// happens outside the measured intervals so the headline events/sec is the
/// pipeline's, not `synth_event`'s.
fn bench_ingest(cfg: &PerfConfig) -> f64 {
    let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
    let mut batch: Vec<TelemetryEvent> = Vec::with_capacity(cfg.ingest_batch);
    let mut produced = 0usize;
    let mut elapsed_ms = 0.0;
    while produced < cfg.ingest_events {
        batch.clear();
        let n = cfg.ingest_batch.min(cfg.ingest_events - produced);
        for k in 0..n {
            batch.push(synth_event(produced + k));
        }
        produced += n;
        // Tick every ~64 batches so accumulator state stays window-sized.
        let tick = produced % (64 * cfg.ingest_batch) < cfg.ingest_batch;
        let timer = PhaseTimer::start();
        plane.ingest(NodeId(0), &batch);
        if tick {
            let _ = plane.window_tick(SimTime(1_000 * produced as u64 + 1));
        }
        elapsed_ms += timer.total_ms();
    }
    let timer = PhaseTimer::start();
    let _ = plane.window_tick(SimTime(1_000 * produced as u64 + 1));
    elapsed_ms + timer.total_ms()
}

/// Phase 2: snapshot latency under a realistic flow population.
fn bench_snapshot(cfg: &PerfConfig) -> Summary {
    let mut accum = WindowAccum::with_hints(NodeId(0), 4, 8);
    let mut lat_us = Summary::new();
    let mut i = 0usize;
    for w in 0..cfg.snapshot_windows {
        for _ in 0..cfg.snapshot_events_per_window {
            accum.ingest(&synth_event(i));
            i += 1;
        }
        // A few flows end each window, exercising the median features.
        for f in 0..4u32 {
            accum.ingest(&TelemetryEvent {
                t: SimTime(1_000 * i as u64),
                node: NodeId(0),
                kind: TelemetryKind::FlowEnd {
                    flow: FlowId((w as u32 * 4 + f) % 64),
                    req: ReqId(w as u32 * 4 + f),
                },
            });
        }
        let timer = PhaseTimer::start();
        let snap = accum.snapshot(SimTime(1_000 * i as u64 + 1));
        lat_us.push(timer.total_ms() * 1e3);
        std::hint::black_box(&snap);
    }
    lat_us
}

/// Run the full perf harness.
pub fn run_perf(cfg: &PerfConfig) -> PerfReport {
    let ingest_ms = bench_ingest(cfg);
    let snap = bench_snapshot(cfg);

    let (matrix_cells, matrix_threads, matrix_ms, matrix_events, matrix_detected) =
        if cfg.micro_only {
            (0, 0, 0.0, 0, 0)
        } else {
            let mc = MatrixConfig {
                replicates: cfg.matrix_replicates,
                threads: cfg.threads,
                ..MatrixConfig::default()
            };
            let rep = run_matrix(&mc);
            (
                rep.cells_run as u64,
                rep.threads_used as u64,
                rep.elapsed_ms,
                rep.events_total,
                rep.detected_count() as u64,
            )
        };

    let (fleet_cells, fleet_threads, fleet_ms, fleet_events) = if cfg.micro_only {
        (0, 0, 0.0, 0)
    } else {
        let mut fc = FleetConfig::new(cfg.fleet_replicas.max(1));
        fc.threads = cfg.threads;
        let rep = run_fleet(&fc);
        (rep.cells_run as u64, rep.threads_used as u64, rep.elapsed_ms, rep.events_total)
    };

    PerfReport {
        quick: cfg.quick,
        ingest_events: cfg.ingest_events as u64,
        ingest_ms,
        snapshot_windows: snap.count() as u64,
        snapshot_p50_us: snap.p50(),
        snapshot_max_us: snap.max(),
        matrix_cells,
        matrix_replicates: cfg.matrix_replicates as u64,
        matrix_threads,
        matrix_ms,
        matrix_events,
        matrix_detected,
        fleet_cells,
        fleet_replicas: cfg.fleet_replicas as u64,
        fleet_threads,
        fleet_ms,
        fleet_events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_cfg() -> PerfConfig {
        PerfConfig {
            ingest_events: 4_000,
            ingest_batch: 256,
            snapshot_windows: 8,
            snapshot_events_per_window: 200,
            matrix_replicates: 1,
            fleet_replicas: 2,
            threads: 1,
            micro_only: true,
            quick: true,
        }
    }

    #[test]
    fn micro_perf_report_has_the_v1_shape() {
        let rep = run_perf(&micro_cfg());
        assert_eq!(rep.ingest_events, 4_000);
        assert_eq!(rep.snapshot_windows, 8);
        assert!(rep.ingest_ms >= 0.0);
        assert!(rep.snapshot_max_us >= rep.snapshot_p50_us);
        let json = rep.to_json().render();
        for key in [
            "\"schema\":\"dpulens.perf.v1\"",
            "\"ingest\"",
            "\"events_per_sec\"",
            "\"snapshot\"",
            "\"p50_us\"",
            "\"matrix\"",
            "\"fleet\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(!json.contains("NaN") && !json.contains("inf"));
    }

    #[test]
    fn synth_mix_covers_visible_and_invisible_classes() {
        let mut visible = 0;
        let mut invisible = 0;
        for i in 0..64 {
            if synth_event(i).kind.dpu_visible() {
                visible += 1;
            } else {
                invisible += 1;
            }
        }
        assert!(visible > 0 && invisible > 0);
    }
}
