//! The `dpulens perf` pipeline benchmark — the measured baseline for the
//! telemetry hot path (see EXPERIMENTS.md §Perf).
//!
//! Five phases, each timed with [`crate::util::perf::PhaseTimer`]:
//!
//! 1. **ingest** — raw batched throughput of the bus → agent → window path:
//!    a synthetic, deterministic event mix streamed through one node's DPU
//!    agent in slices, reported as events/sec;
//! 2. **snapshot** — `WindowAccum::snapshot` latency under a realistic flow
//!    population (p50/max µs over many windows);
//! 3. **iteration** — the decode-iteration microbench: a single replica
//!    pinned at batch 8/64/256 decode lanes, measured over a mid-window
//!    steady-state span (decode rounds/sec and heap bytes per iteration);
//! 4. **matrix** — `run_matrix` end-to-end wall-clock and pipeline events/sec;
//! 5. **fleet** — `run_fleet` end-to-end wall-clock and pipeline events/sec.
//!
//! The JSON form (`BENCH_pipeline.json`, schema `dpulens.perf.v4`) has a
//! deterministic *shape* — fixed keys, deterministic event counts — while
//! the timing values vary by machine; CI uploads it per PR so the bench
//! trajectory accumulates. v3 = v2's keys plus a `reuse` section: the
//! snapshot-and-branch prefix-reuse counters merged across the matrix and
//! fleet end-to-end phases (all zeros under `--micro`). v4 adds an
//! `iteration` section: the decode-iteration microbench (steady-state
//! decode rounds/sec at batch 8/64/256 plus heap bytes allocated per
//! iteration — zero in steady state, asserted by `tests/iter_hot_path.rs`).
//!
//! With `--fleet-stress` a fifth phase runs: healthy multi-pool worlds at
//! 100/250/500/1000 replicas (just 100 under `--quick`), each measured for
//! wall-clock per simulated second, pipeline events/sec, and allocation
//! volume via [`crate::util::alloc`] (the peak-RSS proxy). The optional
//! `fleet_stress` scaling curve keeps its v2 shape — `ci/perf_trajectory.py`
//! compares its points by replica count.

use crate::coordinator::fleet::{multipool_base_cfg, run_fleet, FleetConfig, MultiPoolSpec};
use crate::coordinator::matrix::{run_matrix, MatrixConfig};
use crate::coordinator::scenario::{Scenario, ScenarioCfg};
use crate::coordinator::snapshot::ReuseStats;
use crate::dpu::agent::DpuPlane;
use crate::dpu::detectors::DetectConfig;
use crate::ids::{FlowId, GpuId, NodeId, QpId, ReqId, StageId};
use crate::sim::dist::{Arrival, LengthDist};
use crate::sim::{SimDur, SimTime, MS};
use crate::telemetry::event::{Phase, TelemetryEvent, TelemetryKind};
use crate::telemetry::window::WindowAccum;
use crate::util::json::Json;
use crate::util::perf::{events_per_sec, PhaseTimer};
use crate::util::stats::Summary;

/// Perf-harness configuration.
#[derive(Debug, Clone)]
pub struct PerfConfig {
    /// Synthetic events streamed through the ingest microbench.
    pub ingest_events: usize,
    /// Slice size per batched `DpuPlane::ingest` call.
    pub ingest_batch: usize,
    /// Windows measured in the snapshot-latency microbench.
    pub snapshot_windows: usize,
    /// Events accumulated per measured window.
    pub snapshot_events_per_window: usize,
    /// Seed replicates for the matrix end-to-end phase.
    pub matrix_replicates: usize,
    /// Replica count for the fleet end-to-end phase.
    pub fleet_replicas: usize,
    /// Worker threads for the end-to-end phases; 0 = one per core.
    pub threads: usize,
    /// Skip the (multi-second) matrix/fleet end-to-end phases.
    pub micro_only: bool,
    /// Label recorded in the JSON (`--quick` vs full).
    pub quick: bool,
    /// Optional fleet-scale scaling curve (`--fleet-stress`); adds the
    /// `fleet_stress` section (historically the `dpulens.perf.v2`
    /// addition — the document schema is always v4 today).
    pub fleet_stress: Option<FleetStressConfig>,
}

/// Fleet-stress phase configuration: which replica-count scaling points to
/// run and on how many observe-path workers.
#[derive(Debug, Clone)]
pub struct FleetStressConfig {
    /// Replica counts, one healthy multi-pool world per entry.
    pub points: Vec<usize>,
    /// Observe-path worker threads per world (0 = one per core).
    pub threads: usize,
    /// Shorter simulated duration per point.
    pub quick: bool,
}

impl FleetStressConfig {
    /// CI sizing: the 100-replica point only.
    pub fn quick(threads: usize) -> Self {
        FleetStressConfig { points: vec![100], threads, quick: true }
    }

    /// The full scaling curve up to the paper-scale 1000-replica fleet.
    pub fn full(threads: usize) -> Self {
        FleetStressConfig { points: vec![100, 250, 500, 1000], threads, quick: false }
    }
}

impl PerfConfig {
    /// CI-friendly sizing: small microbenches, one matrix replicate, a
    /// 2-replica fleet.
    pub fn quick() -> Self {
        PerfConfig {
            ingest_events: 200_000,
            ingest_batch: 1024,
            snapshot_windows: 64,
            snapshot_events_per_window: 2_000,
            matrix_replicates: 1,
            fleet_replicas: 2,
            threads: 0,
            micro_only: false,
            quick: true,
            fleet_stress: None,
        }
    }

    /// The full baseline: the acceptance configuration (`matrix
    /// --replicates 3`, 4-replica fleet) plus larger microbenches.
    pub fn full() -> Self {
        PerfConfig {
            ingest_events: 2_000_000,
            ingest_batch: 1024,
            snapshot_windows: 200,
            snapshot_events_per_window: 4_000,
            matrix_replicates: 3,
            fleet_replicas: 4,
            threads: 0,
            micro_only: false,
            quick: false,
            fleet_stress: None,
        }
    }
}

/// Everything one perf run measures.
#[derive(Debug)]
pub struct PerfReport {
    pub quick: bool,
    pub ingest_events: u64,
    pub ingest_ms: f64,
    pub snapshot_windows: u64,
    pub snapshot_p50_us: f64,
    pub snapshot_max_us: f64,
    pub matrix_cells: u64,
    pub matrix_replicates: u64,
    pub matrix_threads: u64,
    pub matrix_ms: f64,
    pub matrix_events: u64,
    pub matrix_detected: u64,
    pub fleet_cells: u64,
    pub fleet_replicas: u64,
    pub fleet_threads: u64,
    pub fleet_ms: f64,
    pub fleet_events: u64,
    /// Snapshot-and-branch prefix-reuse counters, merged across the matrix
    /// and fleet end-to-end phases (all zeros under `--micro`).
    pub reuse: ReuseStats,
    /// The decode-iteration microbench curve, one point per batch size.
    pub iteration: Vec<IterBenchPoint>,
    pub fleet_stress: Option<FleetStressReport>,
}

/// The fleet-stress phase's scaling curve.
#[derive(Debug)]
pub struct FleetStressReport {
    /// Resolved observe-path worker count the points ran on.
    pub threads: u64,
    pub points: Vec<StressPoint>,
}

/// One scaling point: a healthy multi-pool world at `replicas` scale.
#[derive(Debug, Clone)]
pub struct StressPoint {
    pub replicas: u64,
    /// Simulated span, milliseconds.
    pub sim_ms: f64,
    /// Wall-clock for the whole run, milliseconds.
    pub wall_ms: f64,
    /// Telemetry events published through the pipeline.
    pub events: u64,
    /// Requests completed (a sanity anchor — zero means the world stalled).
    pub completed: u64,
    /// Bytes allocated over the run (zeros when the counting allocator is
    /// not registered, i.e. in library unit tests).
    pub alloc_bytes: u64,
    /// High-water mark of live heap bytes during the run (RSS proxy).
    pub peak_alloc_bytes: u64,
}

impl StressPoint {
    pub fn events_per_sec(&self) -> f64 {
        events_per_sec(self.events, self.wall_ms)
    }

    /// Wall milliseconds per simulated second — the scaling headline
    /// (lower is better; linear scaling holds it flat per replica).
    pub fn wall_ms_per_sim_s(&self) -> f64 {
        if self.sim_ms <= 0.0 {
            0.0
        } else {
            self.wall_ms * 1_000.0 / self.sim_ms
        }
    }
}

/// Batch sizes measured by the decode-iteration microbench.
pub const ITER_BATCHES: [usize; 3] = [8, 64, 256];

/// One decode-iteration microbench point: a single replica saturated at
/// `batch` decode lanes, timed over a mid-window steady-state span (no
/// window tick inside the span, reusable-buffer capacities plateaued).
#[derive(Debug, Clone)]
pub struct IterBenchPoint {
    pub batch: u64,
    /// Decode iterations completed in the measured span.
    pub iters: u64,
    /// Wall-clock for the measured span, milliseconds.
    pub wall_ms: f64,
    /// Heap bytes allocated over the measured span (zeros when the counting
    /// allocator is not registered, i.e. in library unit tests).
    pub alloc_bytes: u64,
}

impl IterBenchPoint {
    pub fn iters_per_sec(&self) -> f64 {
        events_per_sec(self.iters, self.wall_ms)
    }

    /// The steady-state headline: heap bytes per decode iteration. Zero on
    /// the v4 hot path — `tests/iter_hot_path.rs` asserts it exactly under
    /// `--features perf-probe`.
    pub fn alloc_bytes_per_iter(&self) -> f64 {
        if self.iters == 0 {
            0.0
        } else {
            self.alloc_bytes as f64 / self.iters as f64
        }
    }
}

impl PerfReport {
    pub fn ingest_events_per_sec(&self) -> f64 {
        events_per_sec(self.ingest_events, self.ingest_ms)
    }

    pub fn matrix_events_per_sec(&self) -> f64 {
        events_per_sec(self.matrix_events, self.matrix_ms)
    }

    pub fn fleet_events_per_sec(&self) -> f64 {
        events_per_sec(self.fleet_events, self.fleet_ms)
    }

    /// `dpulens.perf.v4`: fixed key shape (the `fleet_stress` section only
    /// when that phase ran); timing values machine-dependent.
    pub fn to_json(&self) -> Json {
        let mut iter_pts = Json::arr();
        for p in &self.iteration {
            iter_pts.push(
                Json::obj()
                    .set("batch", p.batch)
                    .set("iters", p.iters)
                    .set("wall_ms", p.wall_ms)
                    .set("iters_per_sec", p.iters_per_sec())
                    .set("alloc_bytes", p.alloc_bytes)
                    .set("alloc_bytes_per_iter", p.alloc_bytes_per_iter()),
            );
        }
        let mut j = Json::obj()
            .set("schema", "dpulens.perf.v4")
            .set("quick", self.quick)
            .set(
                "ingest",
                Json::obj()
                    .set("events", self.ingest_events)
                    .set("elapsed_ms", self.ingest_ms)
                    .set("events_per_sec", self.ingest_events_per_sec()),
            )
            .set(
                "snapshot",
                Json::obj()
                    .set("windows", self.snapshot_windows)
                    .set("p50_us", self.snapshot_p50_us)
                    .set("max_us", self.snapshot_max_us),
            )
            .set("iteration", iter_pts)
            .set(
                "matrix",
                Json::obj()
                    .set("cells", self.matrix_cells)
                    .set("replicates", self.matrix_replicates)
                    .set("threads", self.matrix_threads)
                    .set("elapsed_ms", self.matrix_ms)
                    .set("events", self.matrix_events)
                    .set("events_per_sec", self.matrix_events_per_sec())
                    .set("detected", self.matrix_detected),
            )
            .set(
                "fleet",
                Json::obj()
                    .set("cells", self.fleet_cells)
                    .set("replicas", self.fleet_replicas)
                    .set("threads", self.fleet_threads)
                    .set("elapsed_ms", self.fleet_ms)
                    .set("events", self.fleet_events)
                    .set("events_per_sec", self.fleet_events_per_sec()),
            )
            .set(
                "reuse",
                Json::obj()
                    .set("cells_total", self.reuse.cells_total)
                    .set("prefixes_simulated", self.reuse.prefixes_simulated)
                    .set("forked_branches", self.reuse.forked_branches)
                    .set("sim_ns_saved", self.reuse.sim_ns_saved())
                    .set("reuse_ratio", self.reuse.reuse_ratio()),
            );
        if let Some(fs) = &self.fleet_stress {
            let mut pts = Json::arr();
            for p in &fs.points {
                pts.push(
                    Json::obj()
                        .set("replicas", p.replicas)
                        .set("sim_ms", p.sim_ms)
                        .set("wall_ms", p.wall_ms)
                        .set("events", p.events)
                        .set("events_per_sec", p.events_per_sec())
                        .set("wall_ms_per_sim_s", p.wall_ms_per_sim_s())
                        .set("completed", p.completed)
                        .set("alloc_bytes", p.alloc_bytes)
                        .set("peak_alloc_bytes", p.peak_alloc_bytes),
                );
            }
            j = j.set(
                "fleet_stress",
                Json::obj().set("threads", fs.threads).set("points", pts),
            );
        }
        j
    }

    /// Human-readable summary lines.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "ingest:   {} events in {:.1} ms ({:.0} events/s)\n",
            self.ingest_events,
            self.ingest_ms,
            self.ingest_events_per_sec()
        ));
        s.push_str(&format!(
            "snapshot: {} windows, p50 {:.1} us, max {:.1} us\n",
            self.snapshot_windows, self.snapshot_p50_us, self.snapshot_max_us
        ));
        for p in &self.iteration {
            s.push_str(&format!(
                "iter:     batch {:>3}: {} decode rounds in {:.1} ms \
                 ({:.0} iters/s, {:.1} heap B/iter)\n",
                p.batch,
                p.iters,
                p.wall_ms,
                p.iters_per_sec(),
                p.alloc_bytes_per_iter()
            ));
        }
        if self.matrix_cells > 0 {
            s.push_str(&format!(
                "matrix:   {} cells ({} replicates) in {:.1} ms on {} threads \
                 ({} events, {:.0} events/s), {} conditions detected\n",
                self.matrix_cells,
                self.matrix_replicates,
                self.matrix_ms,
                self.matrix_threads,
                self.matrix_events,
                self.matrix_events_per_sec(),
                self.matrix_detected
            ));
        }
        if self.fleet_cells > 0 {
            s.push_str(&format!(
                "fleet:    {} cells ({} replicas) in {:.1} ms on {} threads \
                 ({} events, {:.0} events/s)\n",
                self.fleet_cells,
                self.fleet_replicas,
                self.fleet_ms,
                self.fleet_threads,
                self.fleet_events,
                self.fleet_events_per_sec()
            ));
        }
        if self.reuse.cells_total > 0 {
            s.push_str(&format!(
                "reuse:    {} cells from {} simulated prefixes ({} forked branches, \
                 {:.0} sim-ms saved, {:.1}x prefix reuse)\n",
                self.reuse.cells_total,
                self.reuse.prefixes_simulated,
                self.reuse.forked_branches,
                self.reuse.sim_ns_saved() as f64 / 1e6,
                self.reuse.reuse_ratio()
            ));
        }
        if let Some(fs) = &self.fleet_stress {
            for p in &fs.points {
                s.push_str(&format!(
                    "stress:   {} replicas: {:.0} ms wall / {:.0} ms sim \
                     ({:.1} wall-ms/sim-s, {} events, {:.0} events/s, \
                     peak alloc {} MiB) on {} threads\n",
                    p.replicas,
                    p.wall_ms,
                    p.sim_ms,
                    p.wall_ms_per_sim_s(),
                    p.events,
                    p.events_per_sec(),
                    p.peak_alloc_bytes >> 20,
                    fs.threads
                ));
            }
        }
        s
    }
}

/// Deterministic synthetic event mix: every DPU-relevant vantage plus the
/// invisible classes (so the visibility filter is part of the measured
/// path). Node 0; timestamps advance 1 µs per event.
fn synth_event(i: usize) -> TelemetryEvent {
    let t = SimTime(1_000 * (i as u64 + 1));
    let kind = match i % 8 {
        0 => TelemetryKind::DmaH2d {
            gpu: GpuId((i % 4) as u32),
            bytes: 4096,
            latency_ns: 500 + (i % 7) as u64 * 100,
            phase: if i % 2 == 0 { Phase::Prefill } else { Phase::Decode },
        },
        1 => TelemetryKind::Doorbell { gpu: GpuId((i % 4) as u32) },
        2 => TelemetryKind::NicRx {
            flow: FlowId((i % 64) as u32),
            bytes: 1500,
            queue_depth: (i % 16) as u32,
        },
        3 => TelemetryKind::NicTx {
            flow: FlowId((i % 64) as u32),
            bytes: 128,
            queue_depth: (i % 16) as u32,
            wait_ns: (i % 1000) as u64,
        },
        4 => TelemetryKind::RdmaOp {
            qp: QpId((i % 16) as u32),
            bytes: 65_536,
            credit_wait_ns: (i % 100) as u64,
            latency_ns: 2_000,
        },
        5 => TelemetryKind::StageHandoff {
            from_stage: StageId(0),
            to_stage: StageId(1),
            bytes: 32_768,
            outbound: false,
            phase: Phase::Decode,
        },
        6 => TelemetryKind::PcieUtil {
            link: crate::ids::LinkId(0),
            busy: (i % 100) as f64 / 100.0,
        },
        _ => TelemetryKind::NvlinkBurst { from: GpuId(0), to: GpuId(1), bytes: 1 << 20 },
    };
    TelemetryEvent { t, node: NodeId(0), kind }
}

/// Phase 1: batched ingest throughput through a one-node DPU plane. Only
/// the ingest/window-tick calls are timed — synthetic event generation
/// happens outside the measured intervals so the headline events/sec is the
/// pipeline's, not `synth_event`'s.
fn bench_ingest(cfg: &PerfConfig) -> f64 {
    let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
    let mut batch: Vec<TelemetryEvent> = Vec::with_capacity(cfg.ingest_batch);
    let mut produced = 0usize;
    let mut elapsed_ms = 0.0;
    while produced < cfg.ingest_events {
        batch.clear();
        let n = cfg.ingest_batch.min(cfg.ingest_events - produced);
        for k in 0..n {
            batch.push(synth_event(produced + k));
        }
        produced += n;
        // Tick every ~64 batches so accumulator state stays window-sized.
        let tick = produced % (64 * cfg.ingest_batch) < cfg.ingest_batch;
        let timer = PhaseTimer::start();
        plane.ingest(NodeId(0), &batch);
        if tick {
            let _ = plane.window_tick(SimTime(1_000 * produced as u64 + 1));
        }
        elapsed_ms += timer.total_ms();
    }
    let timer = PhaseTimer::start();
    let _ = plane.window_tick(SimTime(1_000 * produced as u64 + 1));
    elapsed_ms + timer.total_ms()
}

/// Phase 2: snapshot latency under a realistic flow population.
fn bench_snapshot(cfg: &PerfConfig) -> Summary {
    let mut accum = WindowAccum::with_hints(NodeId(0), 4, 8);
    let mut lat_us = Summary::new();
    let mut i = 0usize;
    for w in 0..cfg.snapshot_windows {
        for _ in 0..cfg.snapshot_events_per_window {
            accum.ingest(&synth_event(i));
            i += 1;
        }
        // A few flows end each window, exercising the median features.
        for f in 0..4u32 {
            accum.ingest(&TelemetryEvent {
                t: SimTime(1_000 * i as u64),
                node: NodeId(0),
                kind: TelemetryKind::FlowEnd {
                    flow: FlowId((w as u32 * 4 + f) % 64),
                    req: ReqId(w as u32 * 4 + f),
                },
            });
        }
        let timer = PhaseTimer::start();
        let snap = accum.snapshot(SimTime(1_000 * i as u64 + 1));
        lat_us.push(timer.total_ms() * 1e3);
        std::hint::black_box(&snap);
    }
    lat_us
}

/// One fleet-stress point's world: a healthy multi-pool serving plane at
/// `replicas` scale (K = M = replicas/100 pools, floor 2), short enough to
/// bench but long enough that warmup + calibration end and the fleet sensor
/// runs live windows.
pub fn stress_cfg(replicas: usize, threads: usize, quick: bool) -> crate::coordinator::ScenarioCfg {
    let pools = (replicas / 100).max(2);
    let mp = MultiPoolSpec { replicas, prefill_pools: pools, decode_pools: pools };
    mp.validate().expect("stress topology must be buildable");
    let mut cfg = multipool_base_cfg(&mp);
    cfg.duration = SimDur::from_ms(if quick { 300 } else { 400 });
    cfg.warmup_windows = 5;
    cfg.calib_windows = 15;
    cfg.observe_threads = threads;
    cfg
}

/// One decode-iteration bench world: exactly `batch` requests arrive up
/// front (then arrivals stop), prompts are tiny, budgets far outlast the
/// bench span, and the KV pool is sized so page growth never fails — a
/// single replica pinned at `batch` decode lanes for the whole run.
pub fn iter_bench_cfg(batch: usize) -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(1_000);
    cfg.window = SimDur::from_ms(20);
    cfg.workload.arrival = Arrival::Poisson { rate: 200_000.0 };
    cfg.workload.prompt_len = LengthDist::Uniform { lo: 8, hi: 8 };
    // Budgets far beyond any tokens the bench span can decode: no request
    // ever retires, so the lanes stay pinned at `batch` for the whole run.
    cfg.workload.output_len = LengthDist::Uniform { lo: 65_536, hi: 65_536 };
    cfg.max_requests = batch;
    cfg.engine.policy.max_batch = batch;
    cfg.engine.policy.queue_cap = batch.max(512);
    // KV pages are pool accounting only (u32 counters), so an oversized
    // pool costs nothing and keeps `append_token` succeeding all run.
    cfg.engine.kv_pages = 1 << 22;
    cfg
}

/// Phase 3: the decode-iteration microbench. Each batch size warms its
/// world past arrival/prefill and several full telemetry windows (so every
/// reusable buffer reaches its plateau capacity), then times a mid-window
/// span containing no window tick: everything in the span is steady-state
/// decode rounds plus their coalesced egress deliveries.
fn bench_decode_iterations(quick: bool) -> Vec<IterBenchPoint> {
    // Window = 20 ms; endpoints sit 2 ms past / 2 ms before a tick.
    let (warm_ms, end_ms) = if quick { (62, 78) } else { (122, 138) };
    ITER_BATCHES
        .iter()
        .map(|&batch| {
            let mut world = Scenario::new(iter_bench_cfg(batch));
            world.run_to(SimTime(warm_ms * MS));
            let iters0 = world.iterations;
            let before = crate::util::alloc::stats();
            let timer = PhaseTimer::start();
            world.run_to(SimTime(end_ms * MS));
            let wall_ms = timer.total_ms();
            let after = crate::util::alloc::stats();
            IterBenchPoint {
                batch: batch as u64,
                iters: world.iterations - iters0,
                wall_ms,
                alloc_bytes: after.allocated - before.allocated,
            }
        })
        .collect()
}

/// Run one scaling point and measure it (wall clock, pipeline events,
/// allocation counters around the run).
fn run_stress_point(replicas: usize, threads: usize, quick: bool) -> StressPoint {
    let cfg = stress_cfg(replicas, threads, quick);
    let sim_ms = cfg.duration.ns() as f64 / 1e6;
    let before = crate::util::alloc::stats();
    crate::util::alloc::reset_peak();
    let timer = PhaseTimer::start();
    let res = Scenario::new(cfg).run();
    let wall_ms = timer.total_ms();
    let after = crate::util::alloc::stats();
    StressPoint {
        replicas: replicas as u64,
        sim_ms,
        wall_ms,
        events: res.telemetry_published,
        completed: res.metrics.completed,
        alloc_bytes: after.allocated - before.allocated,
        peak_alloc_bytes: after.peak,
    }
}

/// Run the full perf harness.
pub fn run_perf(cfg: &PerfConfig) -> PerfReport {
    let ingest_ms = bench_ingest(cfg);
    let snap = bench_snapshot(cfg);
    let iteration = bench_decode_iterations(cfg.quick);
    let mut reuse = ReuseStats::default();

    let (matrix_cells, matrix_threads, matrix_ms, matrix_events, matrix_detected) =
        if cfg.micro_only {
            (0, 0, 0.0, 0, 0)
        } else {
            let mc = MatrixConfig {
                replicates: cfg.matrix_replicates,
                threads: cfg.threads,
                ..MatrixConfig::default()
            };
            let rep = run_matrix(&mc);
            reuse.absorb(rep.reuse);
            (
                rep.cells_run as u64,
                rep.threads_used as u64,
                rep.elapsed_ms,
                rep.events_total,
                rep.detected_count() as u64,
            )
        };

    let (fleet_cells, fleet_threads, fleet_ms, fleet_events) = if cfg.micro_only {
        (0, 0, 0.0, 0)
    } else {
        let mut fc = FleetConfig::new(cfg.fleet_replicas.max(1));
        fc.threads = cfg.threads;
        let rep = run_fleet(&fc);
        reuse.absorb(rep.reuse);
        (rep.cells_run as u64, rep.threads_used as u64, rep.elapsed_ms, rep.events_total)
    };

    let fleet_stress = cfg.fleet_stress.as_ref().map(|fs| {
        let points: Vec<StressPoint> = fs
            .points
            .iter()
            .map(|&r| run_stress_point(r, fs.threads, fs.quick))
            .collect();
        FleetStressReport {
            threads: crate::util::par::resolve_threads(fs.threads, usize::MAX) as u64,
            points,
        }
    });

    PerfReport {
        quick: cfg.quick,
        ingest_events: cfg.ingest_events as u64,
        ingest_ms,
        snapshot_windows: snap.count() as u64,
        snapshot_p50_us: snap.p50(),
        snapshot_max_us: snap.max(),
        matrix_cells,
        matrix_replicates: cfg.matrix_replicates as u64,
        matrix_threads,
        matrix_ms,
        matrix_events,
        matrix_detected,
        fleet_cells,
        fleet_replicas: cfg.fleet_replicas as u64,
        fleet_threads,
        fleet_ms,
        fleet_events,
        reuse,
        iteration,
        fleet_stress,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_cfg() -> PerfConfig {
        PerfConfig {
            ingest_events: 4_000,
            ingest_batch: 256,
            snapshot_windows: 8,
            snapshot_events_per_window: 200,
            matrix_replicates: 1,
            fleet_replicas: 2,
            threads: 1,
            micro_only: true,
            quick: true,
            fleet_stress: None,
        }
    }

    #[test]
    fn micro_perf_report_has_the_v4_shape() {
        let rep = run_perf(&micro_cfg());
        assert_eq!(rep.ingest_events, 4_000);
        assert_eq!(rep.snapshot_windows, 8);
        assert!(rep.ingest_ms >= 0.0);
        assert!(rep.snapshot_max_us >= rep.snapshot_p50_us);
        // --micro skips the end-to-end phases: the reuse counters stay zero
        // but the section is still present (fixed key shape).
        assert_eq!(rep.reuse, ReuseStats::default());
        // The iteration microbench always runs: one point per batch size,
        // each with a non-trivial steady-state span.
        assert_eq!(rep.iteration.len(), ITER_BATCHES.len());
        for (p, &batch) in rep.iteration.iter().zip(ITER_BATCHES.iter()) {
            assert_eq!(p.batch, batch as u64);
            assert!(p.iters > 0, "batch {batch} measured no decode rounds");
            assert!(p.wall_ms > 0.0);
        }
        let json = rep.to_json().render();
        for key in [
            "\"schema\":\"dpulens.perf.v4\"",
            "\"ingest\"",
            "\"events_per_sec\"",
            "\"snapshot\"",
            "\"p50_us\"",
            "\"iteration\"",
            "\"iters_per_sec\"",
            "\"alloc_bytes_per_iter\"",
            "\"batch\":256",
            "\"matrix\"",
            "\"fleet\"",
            "\"reuse\"",
            "\"prefixes_simulated\"",
            "\"forked_branches\"",
            "\"sim_ns_saved\"",
            "\"reuse_ratio\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(!json.contains("NaN") && !json.contains("inf"));
    }

    #[test]
    fn stress_report_keeps_the_fleet_stress_section() {
        let mut cfg = micro_cfg();
        cfg.fleet_stress = Some(FleetStressConfig { points: vec![20], threads: 1, quick: true });
        let rep = run_perf(&cfg);
        let fs = rep.fleet_stress.as_ref().expect("stress phase must run");
        assert_eq!(fs.points.len(), 1);
        assert_eq!(fs.points[0].replicas, 20);
        assert!(fs.points[0].events > 0, "stress world published no telemetry");
        assert!(fs.points[0].completed > 0, "stress world served no requests");
        assert!(fs.points[0].wall_ms > 0.0);
        let json = rep.to_json().render();
        for key in [
            "\"schema\":\"dpulens.perf.v4\"",
            "\"fleet_stress\"",
            "\"replicas\":20",
            "\"wall_ms_per_sim_s\"",
            "\"events_per_sec\"",
            // Present even when zero (the library test binary does not
            // register the counting allocator).
            "\"alloc_bytes\"",
            "\"peak_alloc_bytes\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(!json.contains("NaN") && !json.contains("inf"));
    }

    #[test]
    fn synth_mix_covers_visible_and_invisible_classes() {
        let mut visible = 0;
        let mut invisible = 0;
        for i in 0..64 {
            if synth_event(i).kind.dpu_visible() {
                visible += 1;
            } else {
                invisible += 1;
            }
        }
        assert!(visible > 0 && invisible > 0);
    }
}
