//! The scenario orchestrator: a thin event-loop driver over the decomposed
//! serving plane.
//!
//! One deterministic discrete-event world wires workload → router/admission
//! (`ingress`) → batcher/KV → TP/PP execution over the simulated cluster
//! (`iterate`) → egress, with the DPU plane, SW baseline, and fleet sensor
//! observing (`observe`), injectors creating pathologies, and the mitigation
//! controller closing the loop. World state and construction live in
//! `world`; this module owns only the configuration, the result bundle, and
//! the dispatch loop — byte-deterministic for a given config regardless of
//! host thread counts.

use std::collections::{HashMap, VecDeque};

use crate::cluster::{Cluster, ClusterSpec, Outbox};
use crate::dpu::agent::DpuPlane;
use crate::dpu::attribution::Attribution;
use crate::dpu::detectors::{Condition, Detection};
use crate::dpu::fleet::FleetSensor;
use crate::dpu::swdet::SwSuite;
use crate::dpu::watchdog::FreshnessWatchdog;
use crate::engine::exec::ComputeBackend;
use crate::engine::{Engine, EngineConfig};
use crate::ids::ReqId;
use crate::metrics::ServeMetrics;
use crate::sim::{Engine as Calendar, SimDur, SimTime};
use crate::telemetry::sw::SwWindow;
use crate::telemetry::{TelemetryBus, TelemetryFaults};
use crate::workload::generator::{WorkloadGen, WorkloadSpec};

use super::world::{EgressEntry, Ev, HandoffStats, IterScratch, PendingIter};

/// Scenario configuration.
#[derive(Debug, Clone)]
pub struct ScenarioCfg {
    pub cluster: ClusterSpec,
    pub engine: EngineConfig,
    pub workload: WorkloadSpec,
    pub seed: u64,
    /// Total simulated duration.
    pub duration: SimDur,
    /// DPU/SW window period.
    pub window: SimDur,
    /// Warmup windows discarded before calibration (startup transient).
    pub warmup_windows: u64,
    /// Calibration windows before detectors go live.
    pub calib_windows: u64,
    /// Optional pathology injection: (condition, time).
    pub inject: Option<(Condition, SimTime)>,
    /// Which replica node-scoped injections victimize (clamped to the
    /// cluster's replica count; 0 preserves the single-replica behavior).
    pub victim_replica: usize,
    /// Closed-loop mitigation on detection?
    pub mitigate: bool,
    /// Stop generating new arrivals after this many requests (0 = unlimited).
    pub max_requests: usize,
    /// Event-calendar backend. The default bucket calendar shards per pool
    /// and is the fleet-scale fast path; `Heap` keeps the classic global
    /// binary heap (the equivalence-suite reference). Identical event order
    /// either way.
    pub calendar: crate::sim::CalendarKind,
    /// Worker threads for the per-window observe path (telemetry ingest +
    /// fleet-sensor rule sweep); `1` = the classic serial path. Output is
    /// byte-identical for any value; sweeps that parallelize at the cell
    /// level keep 1 to avoid oversubscription.
    pub observe_threads: usize,
    /// Schedule one calendar event per generated token (the legacy egress
    /// path) instead of one coalesced `Ev::EgressBatch` per iteration.
    /// Output is byte-identical either way — the coalesced lane replays
    /// per-token completions at their exact legacy `(time, seq)` keys —
    /// so this exists only for the equivalence harness.
    pub per_token_egress: bool,
}

impl Default for ScenarioCfg {
    fn default() -> Self {
        ScenarioCfg {
            cluster: ClusterSpec::default(),
            engine: EngineConfig::default(),
            workload: WorkloadSpec::default(),
            seed: 42,
            duration: SimDur::from_ms(2600),
            window: SimDur::from_ms(10),
            warmup_windows: 20,
            calib_windows: 100,
            inject: None,
            victim_replica: 0,
            mitigate: false,
            max_requests: 0,
            calendar: crate::sim::CalendarKind::Bucket,
            observe_threads: 1,
            per_token_egress: false,
        }
    }
}

/// Everything a run produces.
#[derive(Debug)]
pub struct RunResult {
    pub metrics: ServeMetrics,
    /// Per-tenant SLO lanes (`WorkloadSpec::tenants` order); a single
    /// implicit lane when no tenant classes are configured.
    pub tenants: Vec<crate::metrics::TenantLane>,
    /// Requests the workload generator produced (a tail may still be in
    /// flight toward the cluster when the run ends).
    pub requests_generated: usize,
    /// Requests that reached the cluster boundary (`Ev::Arrival` fired).
    pub requests_arrived: usize,
    /// Distinct requests the engine tracked; `< requests_arrived` means
    /// ids collided and bookkeeping was silently overwritten.
    pub requests_tracked: usize,
    pub detections: Vec<Detection>,
    pub attributions: Vec<Attribution>,
    pub sw_detections: usize,
    /// Full software-suite alarm log (what fired, when) — the SW-vs-DPU
    /// coverage comparison needs alarm identities, not just a count.
    pub sw_alarm_log: Vec<crate::dpu::swdet::SwDetection>,
    pub actions: Vec<crate::mitigation::AppliedAction>,
    pub injected_at: Option<SimTime>,
    pub injection_desc: Option<String>,
    pub telemetry_published: u64,
    pub dpu_ingested: u64,
    pub dpu_invisible_dropped: u64,
    pub windows: u64,
    pub iterations: u64,
    /// Per-replica iteration counts (fleet skew view).
    pub replica_iterations: Vec<u64>,
    /// Per-replica cumulative routed arrivals (router accounting).
    pub replica_routed: Vec<u64>,
    /// Peak KV occupancy observed per replica (window-sampled).
    pub replica_kv_peak: Vec<f64>,
    pub real_compute: bool,
    pub class_counts: std::collections::HashMap<&'static str, u64>,
    /// Cumulative prefill→decode KV-handoff accounting (zeros when the
    /// fleet is colocated).
    pub handoffs: HandoffStats,
    /// Handoffs that arrived but were still parked awaiting decode-side
    /// admission when the run ended.
    pub handoffs_parked_at_end: u64,
    /// Telemetry events discarded at the fault boundary (TD1/TD2); zero on
    /// every run that never set a fault mode.
    pub fault_dropped: u64,
    /// Telemetry events still parked in lag hold queues at run end (TD3).
    /// With faults the conservation identity widens to
    /// `published == ingested + invisible + fault_dropped + fault_held`.
    pub fault_held_at_end: u64,
    /// Router-fallback ladder transitions: (window index, new level), one
    /// entry per change. Empty on every never-faulted run.
    pub ladder_transitions: Vec<(u64, u8)>,
}

impl RunResult {
    pub fn detected(&self, c: Condition) -> bool {
        self.detections.iter().any(|d| d.condition == c)
    }

    /// Handoffs launched but not yet landed when the run ended (their bytes
    /// account for any sent/delivered gap).
    pub fn handoffs_inflight_at_end(&self) -> u64 {
        self.handoffs.started - self.handoffs.completed
    }

    pub fn detection_latency(&self, c: Condition) -> Option<SimDur> {
        let t0 = self.injected_at?;
        crate::metrics::detection_latency(&self.detections, c, t0)
    }
}

/// The world: state lives here, behavior is split across the serving-plane
/// sub-modules (`world` construction, `ingress`, `iterate`, `observe`).
pub struct Scenario {
    pub cfg: ScenarioCfg,
    pub cluster: Cluster,
    pub engine: Engine,
    pub dpu: DpuPlane,
    pub sw_suite: SwSuite,
    pub(crate) sw_window: SwWindow,
    pub controller: crate::mitigation::Controller,
    pub(crate) fleet: FleetSensor,
    pub(crate) bus: TelemetryBus,
    pub(crate) cal: Calendar<Ev>,
    /// Replica → calendar shard (shard 0 is the global lane; one shard per
    /// prefill pool, then one per decode pool). Sharding never affects pop
    /// order — ties are broken by the global sequence number — it only keeps
    /// each bucket ring short at fleet scale.
    pub(crate) cal_shard: Vec<usize>,
    pub(crate) gen: WorkloadGen,
    pub(crate) backends: Vec<Box<dyn ComputeBackend>>,
    pub(crate) pending: Vec<Option<PendingIter>>,
    /// Per-replica reusable iteration buffers (see `world::IterScratch`):
    /// the steady-state decode round runs entirely out of these.
    pub(crate) iter_scratch: Vec<IterScratch>,
    /// Per-replica coalesced egress lanes: tokens awaiting their batched
    /// `Ev::EgressBatch` dispatch, in `(done, seq)` order.
    pub(crate) egress_lanes: Vec<VecDeque<EgressEntry>>,
    pub(crate) slot_of: HashMap<ReqId, usize>,
    pub(crate) free_slots: Vec<Vec<usize>>,
    pub(crate) outbox: Outbox,
    pub(crate) windows_seen: u64,
    pub(crate) injected_at: Option<SimTime>,
    pub(crate) injection_desc: Option<String>,
    pub(crate) generated: usize,
    pub(crate) arrived: usize,
    pub(crate) iterations: u64,
    pub(crate) attributions: Vec<Attribution>,
    pub(crate) kv_peak: Vec<f64>,
    /// Arrived-but-unadopted KV handoffs per decode replica (admission was
    /// full on arrival; drained on retire and at window ticks).
    pub(crate) handoff_wait: Vec<VecDeque<ReqId>>,
    /// Collective-id allocator for cross-pool handoff bursts.
    pub(crate) handoff_colls: crate::engine::CollSeq,
    pub(crate) handoff_stats: HandoffStats,
    /// Telemetry fault boundary (TD conditions). Engages lazily on the
    /// first non-None mode in `Cluster::tele_faults`; until then delivery
    /// runs the pristine bus path, byte-identically.
    pub(crate) tele_faults: TelemetryFaults,
    /// Freshness watchdog driving the router-fallback ladder.
    pub(crate) watchdog: FreshnessWatchdog,
    /// Ladder transition log: (window index, new level) per change.
    pub(crate) ladder_log: Vec<(u64, u8)>,
    pub(crate) real_compute: bool,
    /// Loop lifecycle for snapshot/fork execution: `started` makes calendar
    /// arming idempotent across `run_to` + `run`, and `finished` latches
    /// when `Ev::End` pops so resuming past the end is a no-op.
    pub(crate) started: bool,
    pub(crate) finished: bool,
}

impl Scenario {
    /// Arm the calendar (end marker, first window tick, first arrival).
    /// Idempotent: a world advanced by `run_to` and later finished by
    /// `run` arms exactly once.
    fn start(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        let end = SimTime::ZERO + self.cfg.duration;
        self.cal.schedule_at(end, Ev::End);
        self.cal.schedule_in(self.cfg.window, Ev::WindowTick);
        self.schedule_next_arrival();
    }

    /// The dispatch loop. With a `stop`, only events strictly earlier than
    /// it run (`peek < stop`); ties at `stop` stay pending — they belong to
    /// the branch resumed from the checkpoint, which replays them in the
    /// identical `(t, seq)` order a from-scratch run would.
    fn run_loop(&mut self, stop: Option<SimTime>) {
        if self.finished {
            return;
        }
        let end = SimTime::ZERO + self.cfg.duration;
        loop {
            if let Some(stop) = stop {
                match self.cal.peek_time() {
                    Some(t) if t < stop => {}
                    _ => break,
                }
            }
            let Some((now, ev)) = self.cal.pop() else { break };
            match ev {
                Ev::End => {
                    self.finished = true;
                    break;
                }
                Ev::GenNext => self.schedule_next_arrival(),
                Ev::Arrival(req) => self.on_arrival(*req, now),
                Ev::Delivered(id) => self.on_delivered(id, now),
                Ev::Iterate(replica) => {
                    self.pending[replica] = None;
                    self.run_next_iteration(replica, now);
                }
                Ev::IterDone(replica) => self.finish_iteration(replica, now),
                Ev::EgressDone { req, last } => self.on_egress_done(req, last, now),
                Ev::EgressBatch(replica) => self.on_egress_batch(replica),
                Ev::KvHandoffDone { req, to } => self.on_kv_handoff_done(req, to, now),
                Ev::WindowTick => {
                    self.on_window_tick(now);
                    if now < end {
                        self.cal.schedule_in(self.cfg.window, Ev::WindowTick);
                    }
                }
            }
        }
    }

    /// Advance the world up to (not including) `stop` and pause — the
    /// snapshot capture point for fork execution, and the measurement hook
    /// for the decode-iteration microbench and the steady-state hot-path
    /// tests (`tests/iter_hot_path.rs` brackets a mid-window span with it).
    /// Everything scheduled at `t >= stop` stays pending for the resumed
    /// branch.
    pub fn run_to(&mut self, stop: SimTime) {
        self.start();
        self.run_loop(Some(stop));
    }

    /// Engine iterations (prefill batches + decode rounds) completed so far
    /// across all replicas — the denominator for per-iteration measurements
    /// taken around a `run_to` span.
    pub fn iterations_so_far(&self) -> u64 {
        self.iterations
    }

    /// Run to completion (from scratch, or resuming a world advanced by
    /// `run_to`); returns the result bundle.
    pub fn run(mut self) -> RunResult {
        self.start();
        self.run_loop(None);

        // Final partial window: events already buffered with t < end would
        // have been popped from the old calendar before `Ev::End`; deliver
        // them so every observed event is counted (published == ingested +
        // invisible_dropped) and nothing pending leaks into the totals.
        let end = SimTime::ZERO + self.cfg.duration;
        self.deliver_telemetry(end);
        self.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    fn quick_cfg() -> ScenarioCfg {
        let mut cfg = ScenarioCfg::default();
        cfg.duration = SimDur::from_ms(900);
        cfg.window = SimDur::from_ms(10);
        cfg.warmup_windows = 10;
        cfg.calib_windows = 40;
        cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 300.0 };
        cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 32 };
        cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 2, hi: 8 };
        cfg
    }

    #[test]
    fn healthy_run_completes_requests() {
        let res = Scenario::new(quick_cfg()).run();
        assert!(res.metrics.completed > 20, "completed {}", res.metrics.completed);
        assert!(res.telemetry_published > 1000);
        assert!(res.dpu_ingested > 0);
        assert!(res.iterations > 0);
        // Healthy: few or no detections after calibration.
        assert!(
            res.detections.len() < 30,
            "too many false alarms: {:?}",
            res.detections.iter().map(|d| d.condition.id()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Scenario::new(quick_cfg()).run();
        let b = Scenario::new(quick_cfg()).run();
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.telemetry_published, b.telemetry_published);
        assert_eq!(a.detections.len(), b.detections.len());
    }

    #[test]
    fn thin_sessions_delay_only_their_own_requests() {
        // Regression: generation used to chain off request *delivery*, so a
        // thin-session request delayed by 0.5s stalled every arrival behind
        // it (~2 requests generated per second instead of ~300). With the
        // generation clock decoupled, the stream keeps its configured rate.
        let mut cfg = quick_cfg();
        cfg.workload.thin_session_frac = 0.3;
        cfg.workload.thin_extra_gap_s = 0.5;
        let res = Scenario::new(cfg).run();
        // 300 req/s over 0.9s ≈ 270 generated; the pre-fix stall produced
        // single digits. Thin requests themselves may still be in flight.
        assert!(
            res.requests_generated > 150,
            "arrival stream stalled: only {} requests generated",
            res.requests_generated
        );
        assert!(res.metrics.completed > 20, "completed {}", res.metrics.completed);
    }

    #[test]
    fn every_arrived_request_is_tracked() {
        let res = Scenario::new(quick_cfg()).run();
        assert_eq!(res.requests_tracked, res.requests_arrived);
        assert!(res.requests_arrived <= res.requests_generated);
    }

    #[test]
    fn workload_swap_does_not_reissue_live_req_ids() {
        // Regression: a workload-site injection used to rebuild the
        // generator with `next_id` back at 0, so post-swap requests reused
        // live ReqIds and overwrote engine bookkeeping (tracked < arrived).
        let mut cfg = quick_cfg();
        cfg.duration = SimDur::from_ms(1100);
        cfg.inject = Some((Condition::Ns2IngressStarvation, SimTime(600 * MS)));
        let res = Scenario::new(cfg).run();
        assert!(res.injected_at.is_some());
        // The swapped NS2 stream must keep flowing after injection.
        assert!(res.requests_generated > 100, "generated {}", res.requests_generated);
        assert_eq!(
            res.requests_tracked, res.requests_arrived,
            "ReqIds were reused across the workload swap"
        );
    }

    #[test]
    fn injection_is_detected() {
        let mut cfg = quick_cfg();
        cfg.duration = SimDur::from_ms(1100);
        cfg.inject = Some((Condition::Ew6Retransmissions, SimTime(600 * MS)));
        let res = Scenario::new(cfg).run();
        assert!(res.injected_at.is_some());
        assert!(
            res.detected(Condition::Ew6Retransmissions),
            "EW6 not detected; got {:?}",
            res.detections.iter().map(|d| d.condition.id()).collect::<Vec<_>>()
        );
        assert!(res.detection_latency(Condition::Ew6Retransmissions).is_some());
    }

    #[test]
    fn mitigation_heals_the_fabric() {
        let mut cfg = quick_cfg();
        cfg.duration = SimDur::from_ms(1100);
        cfg.inject = Some((Condition::Ew6Retransmissions, SimTime(600 * MS)));
        cfg.mitigate = true;
        let scenario = Scenario::new(cfg);
        let res = scenario.run();
        assert!(!res.actions.is_empty(), "controller took no action");
        assert!(res
            .actions
            .iter()
            .any(|a| a.directive == crate::mitigation::Directive::LosslessFabricConfig));
    }

    #[test]
    fn visibility_boundary_holds_in_full_runs() {
        let res = Scenario::new(quick_cfg()).run();
        // NVLink + GPU kernel events were published but never ingested.
        assert!(res.dpu_invisible_dropped > 0);
        assert_eq!(
            res.dpu_ingested + res.dpu_invisible_dropped,
            res.telemetry_published,
            "every published event either ingested or dropped by visibility"
        );
    }

    #[test]
    fn per_replica_accounting_covers_the_run() {
        let res = Scenario::new(quick_cfg()).run();
        // Single default replica: all completions land on lane 0.
        assert_eq!(res.metrics.per_replica.len(), 1);
        assert_eq!(res.metrics.per_replica[0].completed, res.metrics.completed);
        assert_eq!(res.replica_iterations.iter().sum::<u64>(), res.iterations);
        assert_eq!(res.replica_routed.len(), 1);
        assert!(res.replica_routed[0] > 0);
        assert!(res.replica_kv_peak[0] > 0.0);
    }

    #[test]
    fn multi_replica_world_serves_on_all_replicas() {
        let mut cfg = quick_cfg();
        cfg.engine.nodes_per_stage = 1; // 4 nodes / pp2 => 2 replicas
        let res = Scenario::new(cfg).run();
        assert_eq!(res.metrics.per_replica.len(), 2);
        assert!(res.replica_routed.iter().all(|&n| n > 0), "{:?}", res.replica_routed);
        assert!(
            res.metrics.per_replica.iter().all(|l| l.completed > 0),
            "a replica served nothing: {:?}",
            res.metrics.per_replica
        );
        // Healthy hash routing: no DP fleet alarms.
        assert!(!res.detected(Condition::Dp1RouterFlowSkew));
        assert!(!res.detected(Condition::Dp2HotReplicaKv));
        assert!(!res.detected(Condition::Dp3StragglerReplica));
    }
}
