//! The scenario engine: one deterministic discrete-event world wiring
//! workload → router/admission → batcher/KV → TP/PP execution over the
//! simulated cluster → egress, with the DPU plane observing, the SW baseline
//! sampling, injectors creating pathologies, and the mitigation controller
//! closing the loop.

use crate::cluster::{Cluster, ClusterSpec, Outbox};
use crate::dpu::attribution::{attribute, Attribution};
use crate::dpu::detectors::{Condition, DetectConfig, Detection};
use crate::dpu::agent::DpuPlane;
use crate::dpu::swdet::SwSuite;
use crate::engine::exec::{run_iteration, ComputeBackend, IterKind, SurrogateBackend};
use crate::engine::{build_replicas, Engine, EngineConfig, Work};
use crate::ids::{NodeId, ReqId};
use crate::metrics::ServeMetrics;
use crate::pathology;
use crate::sim::{Engine as Calendar, SimDur, SimTime, MS};
use crate::telemetry::event::{TelemetryEvent, TelemetryKind};
use crate::telemetry::sw::{SwSignal, SwWindow};
use crate::telemetry::TelemetryBus;
use crate::workload::generator::{WorkloadGen, WorkloadSpec};
use crate::workload::request::{InferenceRequest, ReqState};

/// Per-token egress payload bytes (token id + framing).
const TOKEN_EGRESS_BYTES: u64 = 128;
/// Egress response streams get per-request flow ids (a response stream is a
/// stream, not a session): high bit marks them.
fn egress_flow(req: crate::ids::ReqId) -> crate::ids::FlowId {
    crate::ids::FlowId(0x8000_0000 | req.0)
}
/// Per-request ingress overhead bytes.
const INGRESS_OVERHEAD: u64 = 256;

/// Scenario configuration.
#[derive(Debug, Clone)]
pub struct ScenarioCfg {
    pub cluster: ClusterSpec,
    pub engine: EngineConfig,
    pub workload: WorkloadSpec,
    pub seed: u64,
    /// Total simulated duration.
    pub duration: SimDur,
    /// DPU/SW window period.
    pub window: SimDur,
    /// Warmup windows discarded before calibration (startup transient).
    pub warmup_windows: u64,
    /// Calibration windows before detectors go live.
    pub calib_windows: u64,
    /// Optional pathology injection: (condition, time).
    pub inject: Option<(Condition, SimTime)>,
    /// Closed-loop mitigation on detection?
    pub mitigate: bool,
    /// Stop generating new arrivals after this many requests (0 = unlimited).
    pub max_requests: usize,
}

impl Default for ScenarioCfg {
    fn default() -> Self {
        ScenarioCfg {
            cluster: ClusterSpec::default(),
            engine: EngineConfig::default(),
            workload: WorkloadSpec::default(),
            seed: 42,
            duration: SimDur::from_ms(2600),
            window: SimDur::from_ms(10),
            warmup_windows: 20,
            calib_windows: 100,
            inject: None,
            mitigate: false,
            max_requests: 0,
        }
    }
}

/// Pick a sensible victim node for a condition (ingress/PCIe conditions hit
/// an entry node; egress conditions the exit node; EW1 a stage-0 peer).
pub fn target_node_for(c: Condition, engine: &Engine) -> NodeId {
    use Condition::*;
    let plan = &engine.replicas[0].plan;
    match c {
        Ns5EgressBacklog | Ns6EgressJitter | Ns7EgressRetx | Pc2D2hBottleneck
        | Pc10DecodeEarlyStop => plan.exit_nodes()[0],
        Ew1TpStraggler | Ew9EarlyStopSkew => {
            *plan.stages[0].nodes.last().unwrap_or(&plan.entry_nodes()[0])
        }
        _ => plan.entry_nodes()[0],
    }
}

#[derive(Debug, Clone)]
enum Ev {
    Arrival(Box<InferenceRequest>),
    Delivered(ReqId),
    Iterate(usize),
    IterDone(usize),
    EgressDone { req: ReqId, last: bool },
    Telem(Box<TelemetryEvent>),
    WindowTick,
    End,
}

#[derive(Debug)]
struct PendingIter {
    kind: IterKind,
    started: SimTime,
}

/// Everything a run produces.
#[derive(Debug)]
pub struct RunResult {
    pub metrics: ServeMetrics,
    pub detections: Vec<Detection>,
    pub attributions: Vec<Attribution>,
    pub sw_detections: usize,
    /// Full software-suite alarm log (what fired, when) — the SW-vs-DPU
    /// coverage comparison needs alarm identities, not just a count.
    pub sw_alarm_log: Vec<crate::dpu::swdet::SwDetection>,
    pub actions: Vec<crate::mitigation::AppliedAction>,
    pub injected_at: Option<SimTime>,
    pub injection_desc: Option<String>,
    pub telemetry_published: u64,
    pub dpu_ingested: u64,
    pub dpu_invisible_dropped: u64,
    pub windows: u64,
    pub iterations: u64,
    pub real_compute: bool,
    pub class_counts: std::collections::HashMap<&'static str, u64>,
}

impl RunResult {
    pub fn detected(&self, c: Condition) -> bool {
        self.detections.iter().any(|d| d.condition == c)
    }

    pub fn detection_latency(&self, c: Condition) -> Option<SimDur> {
        let t0 = self.injected_at?;
        crate::metrics::detection_latency(&self.detections, c, t0)
    }
}

/// The world.
pub struct Scenario {
    pub cfg: ScenarioCfg,
    pub cluster: Cluster,
    pub engine: Engine,
    pub dpu: DpuPlane,
    pub sw_suite: SwSuite,
    sw_window: SwWindow,
    pub controller: crate::mitigation::Controller,
    bus: TelemetryBus,
    cal: Calendar<Ev>,
    gen: WorkloadGen,
    backends: Vec<Box<dyn ComputeBackend>>,
    pending: Vec<Option<PendingIter>>,
    slot_of: std::collections::HashMap<ReqId, usize>,
    free_slots: Vec<Vec<usize>>,
    outbox: Outbox,
    windows_seen: u64,
    injected_at: Option<SimTime>,
    injection_desc: Option<String>,
    generated: usize,
    iterations: u64,
    attributions: Vec<Attribution>,
    real_compute: bool,
}

impl Scenario {
    /// Build with surrogate (sim-only) compute backends.
    pub fn new(cfg: ScenarioCfg) -> Self {
        let vocab = cfg.engine.profile.vocab;
        let n_rep = {
            let plans = build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage);
            plans.len()
        };
        let backends: Vec<Box<dyn ComputeBackend>> =
            (0..n_rep).map(|_| Box::new(SurrogateBackend::new(vocab)) as Box<dyn ComputeBackend>).collect();
        Self::with_backends(cfg, backends)
    }

    /// Build with caller-provided compute backends (e.g. the real PJRT
    /// `TransformerSession`), one per replica.
    pub fn with_backends(cfg: ScenarioCfg, backends: Vec<Box<dyn ComputeBackend>>) -> Self {
        cfg.cluster.validate().expect("bad cluster spec");
        let plans = build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage);
        assert_eq!(plans.len(), backends.len(), "one backend per replica");
        let engine = Engine::new(cfg.engine.clone(), plans);
        let cluster = Cluster::new(cfg.cluster.clone(), cfg.seed);
        let mut dpu = DpuPlane::new(
            cfg.cluster.n_nodes,
            cfg.cluster.gpus_per_node,
            DetectConfig { nic_bw: cfg.cluster.nic_bw, z_fire: 4.0 },
        );
        dpu.warmup_windows = cfg.warmup_windows;
        let gen = WorkloadGen::new(cfg.workload.clone(), cfg.engine.profile.vocab, cfg.seed);
        let n_rep = engine.n_replicas();
        let max_batch = cfg.engine.policy.max_batch;
        let real = backends.iter().any(|b| b.is_real());
        Scenario {
            cluster,
            dpu,
            sw_suite: SwSuite::new(),
            sw_window: SwWindow::new(),
            controller: crate::mitigation::Controller::new(cfg.mitigate),
            bus: TelemetryBus::new(cfg.cluster.n_nodes),
            cal: Calendar::new(),
            gen,
            backends,
            pending: (0..n_rep).map(|_| None).collect(),
            slot_of: Default::default(),
            free_slots: (0..n_rep).map(|_| (0..max_batch).rev().collect()).collect(),
            outbox: Outbox::new(),
            windows_seen: 0,
            injected_at: None,
            injection_desc: None,
            generated: 0,
            iterations: 0,
            attributions: Vec::new(),
            engine,
            real_compute: real,
            cfg,
        }
    }

    /// Drain hardware-model emissions into the calendar (time-ordered
    /// delivery to observers).
    fn flush_outbox(&mut self) {
        for (t, node, kind) in self.outbox.drain() {
            self.cal.schedule_at(
                t,
                Ev::Telem(Box::new(TelemetryEvent { t, node, kind })),
            );
        }
    }

    fn schedule_next_arrival(&mut self) {
        if self.cfg.max_requests > 0 && self.generated >= self.cfg.max_requests {
            return;
        }
        let req = self.gen.next_request();
        self.generated += 1;
        self.cal.schedule_at(req.arrival, Ev::Arrival(Box::new(req)));
    }

    fn entry_node(&self, replica: usize) -> NodeId {
        self.engine.replicas[replica].plan.entry_nodes()[0]
    }

    fn exit_node(&self, replica: usize) -> NodeId {
        self.engine.replicas[replica].plan.exit_nodes()[0]
    }

    fn kick(&mut self, replica: usize, now: SimTime) {
        if self.pending[replica].is_none() {
            self.cal.schedule_at(now, Ev::Iterate(replica));
            self.pending[replica] = Some(PendingIter {
                kind: IterKind::Decode { reqs: vec![], ctx_lens: vec![] },
                started: now,
            });
            // Placeholder replaced in Iterate; marks the replica busy so we
            // don't double-schedule.
        }
    }

    fn apply_injection(&mut self, now: SimTime) {
        let Some((cond, at)) = self.cfg.inject else { return };
        if self.injected_at.is_some() || now < at {
            return;
        }
        let target = target_node_for(cond, &self.engine);
        let mut wl = self.cfg.workload.clone();
        let desc = pathology::inject(cond, target, &mut self.cluster, &mut self.engine, &mut wl);
        if pathology::site(cond) == pathology::InjectSite::Workload {
            let mut gen = WorkloadGen::new(wl.clone(), self.cfg.engine.profile.vocab, self.cfg.seed ^ 0x5EED);
            gen.fast_forward(now);
            self.gen = gen;
        }
        self.cfg.workload = wl;
        self.injected_at = Some(now);
        self.injection_desc = Some(desc);
    }

    /// Run to completion; returns the result bundle.
    pub fn run(mut self) -> RunResult {
        let end = SimTime::ZERO + self.cfg.duration;
        self.cal.schedule_at(end, Ev::End);
        self.cal.schedule_in(self.cfg.window, Ev::WindowTick);
        self.schedule_next_arrival();

        while let Some((now, ev)) = self.cal.pop() {
            match ev {
                Ev::End => break,
                Ev::Arrival(req) => {
                    let mut req = *req;
                    let replica = self.engine.register(req.clone());
                    let node = self.entry_node(replica);
                    req.assigned_node = Some(node);
                    self.engine.requests.get_mut(&req.id).unwrap().assigned_node = Some(node);
                    self.sw_window.record(SwSignal::RequestArrival, 1.0);
                    self.sw_window.record(SwSignal::SequenceLength, req.prompt_len() as f64);
                    let bytes = req.prompt_len() as u64 * 4 + INGRESS_OVERHEAD;
                    let delivered =
                        self.cluster.ingress(now, node, req.flow, bytes, &mut self.outbox);
                    self.flush_outbox();
                    self.cal.schedule_at(delivered, Ev::Delivered(req.id));
                    self.schedule_next_arrival();
                }
                Ev::Delivered(id) => {
                    let replica = self.engine.placement[&id];
                    let prompt_len = self.engine.request(id).prompt_len() as u32;
                    let ok = self.engine.replicas[replica].batcher.enqueue(id, prompt_len, now);
                    let r = self.engine.request_mut(id);
                    if ok {
                        r.state = ReqState::Queued;
                        r.admitted_at = Some(now);
                    } else {
                        r.state = ReqState::Rejected;
                        self.engine.router.complete(replica);
                    }
                    self.sw_window.record(
                        SwSignal::QueueDepth,
                        self.engine.replicas[replica].batcher.queue_depth() as f64,
                    );
                    self.kick(replica, now);
                }
                Ev::Iterate(replica) => {
                    self.pending[replica] = None;
                    self.run_next_iteration(replica, now);
                }
                Ev::IterDone(replica) => {
                    self.finish_iteration(replica, now);
                }
                Ev::EgressDone { req, last } => {
                    let r = self.engine.request_mut(req);
                    if r.first_token_at.is_none() {
                        r.first_token_at = Some(now);
                    }
                    if last {
                        r.done_at = Some(now);
                        r.state = ReqState::Done;
                        let replica = self.engine.placement[&req];
                        self.engine.router.complete(replica);
                        let node = self.exit_node(replica);
                        let flow = egress_flow(req);
                        self.bus.emit(now, node, TelemetryKind::FlowEnd { flow, req });
                        let ev = TelemetryEvent {
                            t: now,
                            node,
                            kind: TelemetryKind::FlowEnd { flow, req },
                        };
                        self.dpu.ingest(node, std::slice::from_ref(&ev));
                        self.sw_window.record(SwSignal::TransportLatency, 1000.0);
                    }
                }
                Ev::Telem(ev) => {
                    self.bus.publish((*ev).clone());
                    self.dpu.ingest(ev.node, std::slice::from_ref(&*ev));
                }
                Ev::WindowTick => {
                    self.on_window_tick(now);
                    if now < end {
                        self.cal.schedule_in(self.cfg.window, Ev::WindowTick);
                    }
                }
            }
        }

        let span = self.cfg.duration;
        let metrics = ServeMetrics::collect(self.engine.requests.values(), span);
        let sw_alarm_log = std::mem::take(&mut self.sw_suite.detections);
        RunResult {
            metrics,
            detections: std::mem::take(&mut self.dpu.detections),
            attributions: self.attributions,
            sw_detections: sw_alarm_log.len(),
            sw_alarm_log,
            actions: self.controller.log.clone(),
            injected_at: self.injected_at,
            injection_desc: self.injection_desc,
            telemetry_published: self.bus.total_published(),
            dpu_ingested: self.dpu.total_ingested(),
            dpu_invisible_dropped: self.dpu.total_invisible_dropped(),
            windows: self.windows_seen,
            iterations: self.iterations,
            real_compute: self.real_compute,
            class_counts: self.bus.class_counts().clone(),
        }
    }

    fn run_next_iteration(&mut self, replica: usize, now: SimTime) {
        // KV admission happens at prefill-batch formation.
        let work = {
            let rep = &mut self.engine.replicas[replica];
            if !rep.batcher.may_refill() && !rep.batcher.running().is_empty() {
                // Static/no-remap mode with a draining batch: decode only.
                if rep.batcher.running().is_empty() {
                    Work::Idle
                } else {
                    Work::DecodeRound(rep.batcher.running().iter().map(|s| s.req).collect())
                }
            } else {
                rep.batcher.next_work()
            }
        };
        match work {
            Work::Idle => {
                self.pending[replica] = None;
            }
            Work::Prefill(reqs) => {
                // Admit into KV; anything that doesn't fit goes back.
                let mut admitted = Vec::new();
                for id in reqs {
                    let plen = self.engine.request(id).prompt_len() as u32;
                    let rep = &mut self.engine.replicas[replica];
                    if rep.kv.admit(id, plen) == crate::engine::AllocResult::Ok
                        && !self.free_slots[replica].is_empty()
                    {
                        let slot = self.free_slots[replica].pop().unwrap();
                        self.slot_of.insert(id, slot);
                        admitted.push(id);
                    } else {
                        self.engine.replicas[replica].kv.release(id);
                        self.engine.replicas[replica].batcher.enqueue(id, plen, now);
                        break;
                    }
                }
                if admitted.is_empty() {
                    self.pending[replica] = None;
                    return;
                }
                let prompt_lens: Vec<u32> =
                    admitted.iter().map(|id| self.engine.request(*id).prompt_len() as u32).collect();
                for &id in &admitted {
                    let r = self.engine.request_mut(id);
                    r.state = ReqState::Prefilling;
                    r.prefill_start = Some(now);
                }
                let kind = IterKind::Prefill { reqs: admitted, prompt_lens };
                self.execute(replica, now, kind);
            }
            Work::DecodeRound(reqs) => {
                let ctx_lens: Vec<u32> = reqs
                    .iter()
                    .map(|id| {
                        self.engine.replicas[replica]
                            .batcher
                            .running()
                            .iter()
                            .find(|s| s.req == *id)
                            .map(|s| s.position)
                            .unwrap_or(1)
                    })
                    .collect();
                // KV growth for the step.
                for &id in &reqs {
                    let rep = &mut self.engine.replicas[replica];
                    let _ = rep.kv.append_token(id);
                }
                let kind = IterKind::Decode { reqs, ctx_lens };
                self.execute(replica, now, kind);
            }
        }
    }

    fn execute(&mut self, replica: usize, now: SimTime, kind: IterKind) {
        let timing = {
            let rep = &mut self.engine.replicas[replica];
            rep.iterations += 1;
            match &kind {
                IterKind::Prefill { .. } => rep.prefills += 1,
                IterKind::Decode { .. } => rep.decodes += 1,
            }
            run_iteration(
                now,
                &kind,
                &mut self.cluster,
                &rep.plan,
                &self.cfg.engine.profile,
                &mut rep.colls,
                &mut self.outbox,
            )
        };
        self.iterations += 1;
        self.flush_outbox();
        self.sw_window.record(SwSignal::StepTime, (timing.done - now).ns() as f64);
        self.sw_window.record(SwSignal::GpuUtil, 0.8);
        self.sw_window
            .record(SwSignal::KvOccupancy, self.engine.replicas[replica].kv.occupancy());
        self.pending[replica] = Some(PendingIter { kind, started: now });
        self.cal.schedule_at(timing.done, Ev::IterDone(replica));
    }

    fn finish_iteration(&mut self, replica: usize, now: SimTime) {
        let Some(pending) = self.pending[replica].take() else { return };
        match pending.kind {
            IterKind::Prefill { reqs, prompt_lens } => {
                let slots: Vec<usize> = reqs.iter().map(|id| self.slot_of[id]).collect();
                let prompts: Vec<Vec<i32>> =
                    reqs.iter().map(|id| self.engine.request(*id).prompt.clone()).collect();
                let first_tokens = self.backends[replica].prefill(&slots, &prompts);
                let specs: Vec<(ReqId, u32, u32)> = reqs
                    .iter()
                    .zip(&prompt_lens)
                    .map(|(id, &plen)| {
                        (*id, plen, self.engine.request(*id).max_new_tokens as u32)
                    })
                    .collect();
                self.engine.replicas[replica].batcher.start_decode(&specs);
                for ((id, tok), _plen) in reqs.iter().zip(first_tokens).zip(&prompt_lens) {
                    let r = self.engine.request_mut(*id);
                    r.state = ReqState::Decoding;
                    r.generated.push(tok);
                    self.sw_window.record(SwSignal::DecodeProgress, r.generated.len() as f64);
                    let finished = self.engine.replicas[replica].batcher.on_token(*id);
                    self.emit_token(replica, *id, now, finished);
                    if finished {
                        self.retire(replica, *id);
                    }
                }
            }
            IterKind::Decode { reqs, .. } => {
                let slots: Vec<usize> = reqs.iter().map(|id| self.slot_of[id]).collect();
                let last_tokens: Vec<i32> = reqs
                    .iter()
                    .map(|id| *self.engine.request(*id).generated.last().unwrap_or(&1))
                    .collect();
                let positions: Vec<u32> = reqs
                    .iter()
                    .map(|id| {
                        self.engine.replicas[replica]
                            .batcher
                            .running()
                            .iter()
                            .find(|s| s.req == *id)
                            .map(|s| s.position)
                            .unwrap_or(1)
                            .min(self.cfg.engine.profile.max_seq as u32 - 1)
                    })
                    .collect();
                let next = self.backends[replica].decode(&slots, &last_tokens, &positions);
                for (id, tok) in reqs.iter().zip(next) {
                    let r = self.engine.request_mut(*id);
                    r.generated.push(tok);
                    let finished = self.engine.replicas[replica].batcher.on_token(*id);
                    self.emit_token(replica, *id, now, finished);
                    if finished {
                        self.retire(replica, *id);
                    }
                }
            }
        }
        self.kick(replica, now);
    }

    fn emit_token(&mut self, replica: usize, id: ReqId, now: SimTime, last: bool) {
        let node = self.exit_node(replica);
        let flow = egress_flow(id);
        let done = self.cluster.egress(now, node, flow, TOKEN_EGRESS_BYTES, &mut self.outbox);
        self.flush_outbox();
        self.cal.schedule_at(done, Ev::EgressDone { req: id, last });
    }

    fn retire(&mut self, replica: usize, id: ReqId) {
        self.engine.replicas[replica].batcher.finish(id);
        self.engine.replicas[replica].kv.release(id);
        if let Some(slot) = self.slot_of.remove(&id) {
            self.free_slots[replica].push(slot);
        }
    }

    fn on_window_tick(&mut self, now: SimTime) {
        self.windows_seen += 1;
        self.cluster.on_window_tick(now, self.cfg.window.ns(), &mut self.outbox);
        self.flush_outbox();
        // Calibration -> live transition.
        if self.dpu.is_calibrating()
            && self.windows_seen >= self.cfg.warmup_windows + self.cfg.calib_windows
        {
            self.dpu.go_live();
            self.sw_suite.go_live();
        }
        let detections = self.dpu.window_tick(now);
        let sw_snap = self.sw_window.snapshot(now);
        let _ = self.sw_suite.window_tick(&sw_snap);
        if !detections.is_empty() {
            self.attributions.extend(attribute(&detections));
            self.controller.react(now, &detections, &mut self.cluster, &mut self.engine);
        }
        // Injection is applied at window granularity (after calibration).
        if !self.dpu.is_calibrating() {
            self.apply_injection(now);
        }
        // Keep replicas alive (an idle replica with queued work can stall if
        // a kick was missed during rejection paths).
        for r in 0..self.engine.n_replicas() {
            if self.pending[r].is_none()
                && (self.engine.replicas[r].batcher.queue_depth() > 0
                    || !self.engine.replicas[r].batcher.running().is_empty())
            {
                self.kick(r, now);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ScenarioCfg {
        let mut cfg = ScenarioCfg::default();
        cfg.duration = SimDur::from_ms(900);
        cfg.window = SimDur::from_ms(10);
        cfg.warmup_windows = 10;
        cfg.calib_windows = 40;
        cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 300.0 };
        cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 32 };
        cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 2, hi: 8 };
        cfg
    }

    #[test]
    fn healthy_run_completes_requests() {
        let res = Scenario::new(quick_cfg()).run();
        assert!(res.metrics.completed > 20, "completed {}", res.metrics.completed);
        assert!(res.telemetry_published > 1000);
        assert!(res.dpu_ingested > 0);
        assert!(res.iterations > 0);
        // Healthy: few or no detections after calibration.
        assert!(
            res.detections.len() < 30,
            "too many false alarms: {:?}",
            res.detections.iter().map(|d| d.condition.id()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Scenario::new(quick_cfg()).run();
        let b = Scenario::new(quick_cfg()).run();
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.telemetry_published, b.telemetry_published);
        assert_eq!(a.detections.len(), b.detections.len());
    }

    #[test]
    fn injection_is_detected() {
        let mut cfg = quick_cfg();
        cfg.duration = SimDur::from_ms(1100);
        cfg.inject = Some((Condition::Ew6Retransmissions, SimTime(600 * MS)));
        let res = Scenario::new(cfg).run();
        assert!(res.injected_at.is_some());
        assert!(
            res.detected(Condition::Ew6Retransmissions),
            "EW6 not detected; got {:?}",
            res.detections.iter().map(|d| d.condition.id()).collect::<Vec<_>>()
        );
        assert!(res.detection_latency(Condition::Ew6Retransmissions).is_some());
    }

    #[test]
    fn mitigation_heals_the_fabric() {
        let mut cfg = quick_cfg();
        cfg.duration = SimDur::from_ms(1100);
        cfg.inject = Some((Condition::Ew6Retransmissions, SimTime(600 * MS)));
        cfg.mitigate = true;
        let scenario = Scenario::new(cfg);
        let res = scenario.run();
        assert!(!res.actions.is_empty(), "controller took no action");
        assert!(res
            .actions
            .iter()
            .any(|a| a.directive == crate::mitigation::Directive::LosslessFabricConfig));
    }

    #[test]
    fn visibility_boundary_holds_in_full_runs() {
        let res = Scenario::new(quick_cfg()).run();
        // NVLink + GPU kernel events were published but never ingested.
        assert!(res.dpu_invisible_dropped > 0);
        assert_eq!(
            res.dpu_ingested + res.dpu_invisible_dropped,
            res.telemetry_published,
            "every published event either ingested or dropped by visibility"
        );
    }
}
