//! Observation plane of the scenario loop: telemetry fan-in to the DPU
//! agents, the DPU/SW window cadence (calibration → live), the fleet skew
//! sensor fed from the router vantage, and the closed mitigation loop.

use crate::dpu::attribution::attribute;
use crate::dpu::fleet::{FleetSample, PdSample, TdSample};
use crate::sim::SimTime;
use crate::telemetry::faults::FreshnessStat;

use super::scenario::Scenario;

impl Scenario {
    /// Single-dispatch fan-out: hand every buffered event with `t < now` to
    /// its node's DPU agent as one time-ordered slice. Events are borrowed
    /// from the bus's reusable buffers — zero clones on this path (the
    /// optional bus recorder is the only clone site). An event stamped
    /// exactly at the tick belongs to the next window (see
    /// `TelemetryBus::deliver_due` for the tie-break fine print).
    pub(crate) fn deliver_telemetry(&mut self, now: SimTime) {
        let dpu = &mut self.dpu;
        if self.tele_faults.check_engaged(&self.cluster.tele_faults) {
            // TD fault boundary: once any node has ever carried a fault
            // mode, delivery routes through the fault layer for the rest of
            // the run (recovery — ages resetting, backlogs flushing — is
            // tracked there too). Always serial: the fault path trades the
            // parallel fan-out for thread-stable drop/hold bookkeeping.
            self.tele_faults.deliver_due_faulted(
                &mut self.bus,
                now,
                &self.cluster.tele_faults,
                |node, events| dpu.ingest(node, events),
            );
        } else if self.cfg.observe_threads == 1 {
            self.bus.deliver_due(now, |node, events| dpu.ingest(node, events));
        } else {
            // Fan the per-node buffers out across workers; accounting is
            // reduced with order-independent sums, so this is byte-identical
            // to the serial path for any thread count.
            dpu.ingest_due_parallel(&mut self.bus, now);
        }
    }

    /// Window cadence: deliver the window's telemetry batches, close DPU/SW
    /// windows, run detectors (or calibrate), feed the fleet sensor, react,
    /// and apply pending injections.
    pub(crate) fn on_window_tick(&mut self, now: SimTime) {
        // Deliver before this tick's own hardware-model emissions are
        // flushed: window-tick emissions (and anything stamped >= now)
        // accumulate into the *next* window, exactly as the calendar
        // delivered them after this tick.
        self.deliver_telemetry(now);
        self.windows_seen += 1;
        self.cluster.on_window_tick(now, self.cfg.window.ns(), &mut self.outbox);
        self.flush_outbox();
        // Calibration -> live transition.
        if self.dpu.is_calibrating()
            && self.windows_seen >= self.cfg.warmup_windows + self.cfg.calib_windows
        {
            self.dpu.go_live();
            self.sw_suite.go_live();
        }
        let mut detections = self.dpu.window_tick(now);
        let sw_snap = self.sw_window.snapshot(now);
        let _ = self.sw_suite.window_tick(&sw_snap);

        // Fleet vantage: refresh the router's per-replica telemetry, track
        // KV peaks, and run the cross-replica DP skew sensor once live.
        let n = self.engine.n_replicas();
        let mut queue_depth = Vec::with_capacity(n);
        let mut kv_occ = Vec::with_capacity(n);
        for r in 0..n {
            let qd = self.engine.replicas[r].batcher.queue_depth() as u64;
            let occ = self.engine.replicas[r].kv.occupancy();
            if occ > self.kv_peak[r] {
                self.kv_peak[r] = occ;
            }
            queue_depth.push(qd);
            kv_occ.push(occ);
        }
        let faults_on = self.tele_faults.is_engaged();
        for r in 0..n {
            let fresh = (queue_depth[r] as f64, kv_occ[r]);
            let gauge = if faults_on {
                // The router's weighted-policy feed rides the same faulted
                // path as the event stream: a frozen node's gauges never
                // update, a lossy node's update sometimes, a lagging node's
                // arrive windows stale.
                let node = self.entry_node(r).idx();
                self.tele_faults.rot_gauge(node, self.cluster.tele_faults[node], fresh)
            } else {
                Some(fresh)
            };
            if let Some((qd, occ)) = gauge {
                self.engine.router.update_telemetry(r, qd, occ);
                self.engine.decode_router.update_telemetry(r, qd, occ);
            }
        }
        // Disaggregated fleets: decode capacity freed since the last tick
        // may be able to seat parked handoffs even if no retirement ran.
        if self.engine.is_disaggregated() {
            for r in 0..n {
                if !self.handoff_wait[r].is_empty() {
                    self.drain_handoff_wait(r, now);
                }
            }
        }
        if !self.dpu.is_calibrating() {
            // Mitigation may have shifted replica roles since the last
            // window; skew is judged against the *current* pools.
            self.fleet.sync_pools(self.engine.pools());
            let sample = FleetSample {
                routed: self.engine.router.routed_per_replica().to_vec(),
                queue_depth: queue_depth.clone(),
                kv_occupancy: kv_occ,
                iterations: self.engine.replicas.iter().map(|r| r.iterations).collect(),
                alloc_failures: self.engine.replicas.iter().map(|r| r.kv.alloc_failures).collect(),
            };
            let fleet_fired = self.fleet.window_tick(now, sample);
            if !fleet_fired.is_empty() {
                // Fleet detections join the DPU log: one detection stream
                // feeds attribution, mitigation, and the result bundle.
                self.dpu.detections.extend(fleet_fired.iter().cloned());
                detections.extend(fleet_fired);
            }
            if self.engine.is_disaggregated() {
                let pd = PdSample {
                    prefill_queue: queue_depth,
                    decode_running: self
                        .engine
                        .replicas
                        .iter()
                        .map(|r| r.batcher.lanes().len() as u64)
                        .collect(),
                    decode_slots: self
                        .engine
                        .replicas
                        .iter()
                        .map(|r| r.batcher.policy().max_batch as u64)
                        .collect(),
                    handoff_arrivals: self.handoff_stats.arrivals_per_replica.clone(),
                    handoffs_started: self.handoff_stats.started,
                    handoffs_completed: self.handoff_stats.completed,
                    handoff_lat_sum_ns: self.handoff_stats.lat_sum_ns,
                    handoff_bytes: self.handoff_stats.bytes_delivered,
                    stalled_wait_depth: self.handoff_wait.iter().map(|q| q.len() as u64).sum(),
                };
                let pd_fired = self.fleet.pd_window_tick(now, pd);
                if !pd_fired.is_empty() {
                    self.dpu.detections.extend(pd_fired.iter().cloned());
                    detections.extend(pd_fired);
                }
            }
            if faults_on {
                // TD vantage: the DPU always knows the health of its own
                // inbox. Fold each replica's entry-node freshness into the
                // TD sample (detection) and the watchdog (ladder level).
                let mut td = TdSample {
                    age_windows: Vec::with_capacity(n),
                    emitted: Vec::with_capacity(n),
                    delivered: Vec::with_capacity(n),
                    dropped: Vec::with_capacity(n),
                    held: Vec::with_capacity(n),
                    lag_windows: Vec::with_capacity(n),
                };
                let mut replica_stats: Vec<FreshnessStat> = Vec::with_capacity(n);
                for r in 0..n {
                    let s = self.tele_faults.stats()[self.entry_node(r).idx()];
                    td.age_windows.push(s.age_windows);
                    td.emitted.push(s.emitted);
                    td.delivered.push(s.delivered);
                    td.dropped.push(s.dropped);
                    td.held.push(s.held);
                    td.lag_windows.push(s.lag_windows);
                    replica_stats.push(s);
                }
                let td_fired = self.fleet.td_window_tick(now, td);
                if !td_fired.is_empty() {
                    self.dpu.detections.extend(td_fired.iter().cloned());
                    detections.extend(td_fired);
                }
                // Freshness watchdog → staged router fallback: both routers
                // of the plane degrade and recover together (they share the
                // one telemetry feed).
                let level = self.watchdog.window_tick(&replica_stats);
                if level != self.engine.router.degraded_level() {
                    self.ladder_log.push((self.windows_seen, level));
                }
                self.engine.router.set_degraded_level(level);
                self.engine.decode_router.set_degraded_level(level);
            }
        }

        if !detections.is_empty() {
            self.attributions.extend(attribute(&detections));
            self.controller.react(now, &detections, &mut self.cluster, &mut self.engine);
        }
        // Injection is applied at window granularity (after calibration).
        if !self.dpu.is_calibrating() {
            self.apply_injection(now);
        }
        // Keep replicas alive (an idle replica with queued work can stall if
        // a kick was missed during rejection paths).
        for r in 0..self.engine.n_replicas() {
            if self.pending[r].is_none()
                && (self.engine.replicas[r].batcher.queue_depth() > 0
                    || !self.engine.replicas[r].batcher.lanes().is_empty())
            {
                self.kick(r, now);
            }
        }
    }
}
