//! Ingress/egress boundary of the serving plane: request arrival, routing
//! and admission, egress completion accounting, and pathology injection
//! targeting (including the replica-aware victim selection the fleet
//! scenarios use).

use crate::dpu::detectors::Condition;
use crate::engine::Engine;
use crate::ids::{FlowId, NodeId, ReqId};
use crate::pathology;
use crate::sim::SimTime;
use crate::telemetry::event::TelemetryKind;
use crate::telemetry::sw::SwSignal;
use crate::workload::generator::WorkloadGen;
use crate::workload::request::{InferenceRequest, ReqState};

use super::scenario::Scenario;
use super::world::Ev;

/// Per-token egress payload bytes (token id + framing).
pub(crate) const TOKEN_EGRESS_BYTES: u64 = 128;
/// Per-request ingress overhead bytes.
const INGRESS_OVERHEAD: u64 = 256;

/// Egress response streams get per-request flow ids (a response stream is a
/// stream, not a session): high bit marks them.
pub(crate) fn egress_flow(req: ReqId) -> FlowId {
    FlowId(0x8000_0000 | req.0)
}

/// Pick a sensible victim node for a condition on `replica` (ingress/PCIe
/// conditions hit an entry node; egress conditions the exit node; EW1 a
/// stage-0 peer; DP conditions resolve their victim replica from this node).
/// `replica` is clamped to the cluster's replica count.
pub fn target_node_for(c: Condition, engine: &Engine, replica: usize) -> NodeId {
    use Condition::*;
    let replica = replica.min(engine.n_replicas() - 1);
    let plan = &engine.replicas[replica].plan;
    match c {
        Ns5EgressBacklog | Ns6EgressJitter | Ns7EgressRetx | Pc2D2hBottleneck
        | Pc10DecodeEarlyStop => plan.exit_nodes()[0],
        Ew1TpStraggler | Ew9EarlyStopSkew => {
            *plan.stages[0].nodes.last().unwrap_or(&plan.entry_nodes()[0])
        }
        _ => plan.entry_nodes()[0],
    }
}

impl Scenario {
    /// A request reaches the cluster boundary: route it and start its
    /// ingress transfer. (Generation is chained separately via `Ev::GenNext`
    /// at the generator's undelayed clock — a late-delivered thin-session
    /// request must not gate the stream behind it.)
    pub(crate) fn on_arrival(&mut self, mut req: InferenceRequest, now: SimTime) {
        self.arrived += 1;
        let replica = self.engine.register(req.clone());
        let node = self.entry_node(replica);
        req.assigned_node = Some(node);
        self.engine.requests.get_mut(&req.id).unwrap().assigned_node = Some(node);
        self.sw_window.record(SwSignal::RequestArrival, 1.0);
        self.sw_window.record(SwSignal::SequenceLength, req.prompt_len() as f64);
        let bytes = req.prompt_len() as u64 * 4 + INGRESS_OVERHEAD;
        let delivered = self.cluster.ingress(now, node, req.flow, bytes, &mut self.outbox);
        self.flush_outbox();
        self.schedule_replica_at(replica, delivered, Ev::Delivered(req.id));
    }

    /// Ingress transfer done: admit into the replica's batcher (or reject).
    pub(crate) fn on_delivered(&mut self, id: ReqId, now: SimTime) {
        let replica = self.engine.placement[&id];
        let prompt_len = self.engine.request(id).prompt_len() as u32;
        let ok = self.engine.replicas[replica].batcher.enqueue(id, prompt_len, now);
        let r = self.engine.request_mut(id);
        if ok {
            r.state = ReqState::Queued;
            r.admitted_at = Some(now);
        } else {
            r.state = ReqState::Rejected;
            self.engine.router.complete(replica);
        }
        self.sw_window.record(
            SwSignal::QueueDepth,
            self.engine.replicas[replica].batcher.queue_depth() as f64,
        );
        self.kick(replica, now);
    }

    /// A response-stream chunk finished leaving the exit node.
    pub(crate) fn on_egress_done(&mut self, req: ReqId, last: bool, now: SimTime) {
        let r = self.engine.request_mut(req);
        if r.first_token_at.is_none() {
            r.first_token_at = Some(now);
        }
        if last {
            r.done_at = Some(now);
            r.state = ReqState::Done;
            let transitioned = r.transitioned();
            let replica = self.engine.placement[&req];
            // A request that crossed the pool boundary closed its admission
            // accounting at the handoff; its terminal completion belongs to
            // the decode router.
            if transitioned {
                self.engine.decode_router.complete(replica);
            } else {
                self.engine.router.complete(replica);
            }
            let node = self.exit_node(replica);
            let flow = egress_flow(req);
            // Single dispatch: the bus delivers this to the node's DPU agent
            // with the rest of the window's batch (no side-channel ingest).
            self.bus.emit(now, node, TelemetryKind::FlowEnd { flow, req });
            self.sw_window.record(SwSignal::TransportLatency, 1000.0);
        }
    }

    /// Apply the configured injection once its time arrives (at window
    /// granularity, after calibration).
    pub(crate) fn apply_injection(&mut self, now: SimTime) {
        let Some((cond, at)) = self.cfg.inject else { return };
        if self.injected_at.is_some() || now < at {
            return;
        }
        let target = target_node_for(cond, &self.engine, self.cfg.victim_replica);
        let mut wl = self.cfg.workload.clone();
        let desc = pathology::inject(cond, target, &mut self.cluster, &mut self.engine, &mut wl);
        if pathology::site(cond) == pathology::InjectSite::Workload {
            // Resume, don't restart: a fresh generator would reissue ReqIds
            // starting at 0 and silently overwrite live engine bookkeeping.
            let mut gen = WorkloadGen::resume(
                wl.clone(),
                self.cfg.engine.profile.vocab,
                self.cfg.seed ^ 0x5EED,
                &self.gen,
            );
            gen.fast_forward(now);
            self.gen = gen;
        }
        self.cfg.workload = wl;
        self.injected_at = Some(now);
        self.injection_desc = Some(desc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::engine::{build_replicas, EngineConfig};

    fn fleet_engine() -> Engine {
        let mut cfg = EngineConfig::default();
        cfg.nodes_per_stage = 1; // 4 nodes / pp2 => 2 replicas
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, 1);
        Engine::new(cfg, plans)
    }

    #[test]
    fn victim_selection_is_replica_aware() {
        let e = fleet_engine();
        let r0 = target_node_for(Condition::Pc1H2dStarvation, &e, 0);
        let r1 = target_node_for(Condition::Pc1H2dStarvation, &e, 1);
        assert_ne!(r0, r1, "replica 1 must get its own victim node");
        assert_eq!(r1, e.replicas[1].plan.entry_nodes()[0]);
        // Egress-side conditions target the exit node of the same replica.
        let x1 = target_node_for(Condition::Ns5EgressBacklog, &e, 1);
        assert_eq!(x1, e.replicas[1].plan.exit_nodes()[0]);
        // Out-of-range victims clamp instead of panicking.
        assert_eq!(target_node_for(Condition::Pc1H2dStarvation, &e, 99), r1);
    }

    #[test]
    fn egress_flows_are_marked() {
        assert_eq!(egress_flow(ReqId(5)).0, 0x8000_0005);
    }
}
