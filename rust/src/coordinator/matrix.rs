//! The scenario-matrix runner: the paper's central evaluation (§§4.1-4.3,
//! Tables 3a-c) as a first-class, parallel, machine-readable subsystem.
//!
//! One matrix run executes, fanned out over scoped worker threads
//! (`util::par` — the sim is deterministic and every cell is independent):
//!
//! * `replicates` healthy control runs (false-alarm floor),
//! * `replicates` injected runs per runbook condition (28 × R cells, each
//!   with the per-condition scenario shaping the detection benches proved
//!   out), and
//! * `replicates` §4.3 negative-control runs (TP pinned to NVLink via
//!   single-node stages: an injected GPU straggler must stay invisible).
//!
//! Replicates vary only the scenario seed (`base.seed + rep`), so replicate
//! 0 reproduces the serial bench bit-for-bit. The aggregate is a
//! per-condition [`Scorecard`] (recall, time-to-detect, false-positive rate
//! against the other 27 injections, attribution accuracy, DPU-vs-SW
//! coverage) plus the full injection × detection [`ConfusionMatrix`],
//! emitted as a paper-style table and as deterministic JSON for
//! `BENCH_*.json` trajectory tracking. Two runs with the same config produce
//! byte-identical JSON regardless of thread count.

use std::collections::BTreeMap;

use crate::coordinator::experiment::{
    condition_experiment, inject_time, standard_cfg, ConditionReport,
};
use crate::coordinator::scenario::{Scenario, ScenarioCfg};
use crate::dpu::attribution::RootCause;
use crate::dpu::detectors::{Condition, ALL_CONDITIONS};
use crate::dpu::runbook;
use crate::dpu::swdet;
use crate::engine::preset;
use crate::metrics::{ConfusionMatrix, Scorecard};
use crate::sim::SimTime;
use crate::util::json::Json;
use crate::util::par::{parallel_map, resolve_threads};
use crate::util::table::{fmt_ns, Table};

/// Matrix-run configuration.
#[derive(Debug, Clone)]
pub struct MatrixConfig {
    /// Base scenario every cell derives from (duration, windows, seed...).
    pub base: ScenarioCfg,
    /// Seed-replicated runs per condition (seeds `base.seed + 0..R`).
    pub replicates: usize,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Include the §4.3 NVLink-blindness negative control cells.
    pub negative_control: bool,
}

impl Default for MatrixConfig {
    fn default() -> Self {
        MatrixConfig {
            base: standard_cfg(),
            replicates: 3,
            threads: 0,
            negative_control: true,
        }
    }
}

impl MatrixConfig {
    /// Single replicate on the standard shaped configs — the fastest
    /// configuration the full 28/28 diagonal is still proven on.
    pub fn fast() -> Self {
        MatrixConfig { replicates: 1, ..MatrixConfig::default() }
    }
}

/// Per-condition scenario shaping (see DESIGN.md §4): some runbook rows only
/// produce their red flag under a compute-dominated profile or a saturated
/// decode pool. Shared by the matrix, the sweep CLI, and the benches.
pub fn shaped_cfg(c: Condition, base: &ScenarioCfg) -> ScenarioCfg {
    let mut cfg = base.clone();
    match c {
        // Compute-skew conditions need a compute-dominated cost profile for
        // a straggler/mispartition to move collective timing.
        Condition::Ew1TpStraggler
        | Condition::Ew3CrossNodeSkew
        | Condition::Ew4Congestion
        | Condition::Ew9EarlyStopSkew => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 150.0 };
        }
        // Pipeline-cadence detection needs a *busy* pipeline: idle lulls
        // produce ms-scale healthy gaps that mask a mispartitioned stage.
        Condition::Ew2PpBubble => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 500.0 };
            cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
        }
        // Early-stop conditions only bite when decode slots are saturated.
        Condition::Ns8EarlyCompletion => {
            cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 2000.0 };
            cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
            cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 24 };
        }
        // PC10's PCIe signature (shrinking decode D2H blocks) additionally
        // needs iterations slow enough that slots actually fill: use the
        // compute-heavy profile under sustained demand.
        Condition::Pc10DecodeEarlyStop => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 1500.0 };
            cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
            cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 24 };
        }
        _ => {}
    }
    cfg
}

/// Which root-cause classes count as a correct attribution per condition.
/// EW1-EW3 accept both verdicts of the §4.2 refinement: GPU/host-side when a
/// PCIe-vantage anomaly corroborates, network-side when PCIe looks healthy.
pub fn expected_cause_classes(c: Condition) -> &'static [&'static str] {
    use Condition::*;
    match c {
        Ns1BurstBacklog | Ns2IngressStarvation | Ns3FlowSkew => &["client"],
        Ns4IngressRetx | Ns5EgressBacklog | Ns6EgressJitter | Ns7EgressRetx
        | Ns9BandwidthSaturation => &["network"],
        Ns8EarlyCompletion | Pc10DecodeEarlyStop | Ew9EarlyStopSkew => &["workload"],
        Pc1H2dStarvation | Pc2D2hBottleneck | Pc3LaunchLatency | Pc5PcieSaturation
        | Pc6P2pThrottling | Pc7PinnedShortage | Pc8HostCpuBottleneck
        | Pc9RegistrationChurn => &["host"],
        Pc4IntraNodeSkew => &["gpu"],
        Ew1TpStraggler | Ew2PpBubble | Ew3CrossNodeSkew => &["gpu", "network"],
        Ew4Congestion | Ew5HolBlocking | Ew6Retransmissions | Ew7CreditStarvation
        | Ew8KvBottleneck => &["network"],
    }
}

/// Cause-class label of an attribution verdict.
pub(crate) fn cause_class(c: &RootCause) -> &'static str {
    match c {
        RootCause::HostLocal(_) => "host",
        RootCause::GpuSide(_) => "gpu",
        RootCause::NetworkSide => "network",
        RootCause::WorkloadShape => "workload",
        RootCause::ClientSide => "client",
    }
}

/// One cell of the matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// No injection: false-alarm floor.
    Healthy { rep: usize },
    /// Condition injected after calibration.
    Injected { condition: Condition, rep: usize },
    /// §4.3 control: EW1 straggler with TP pinned to NVLink (invisible).
    NegativeControl { rep: usize },
}

impl CellKind {
    fn injected(self) -> Option<Condition> {
        match self {
            CellKind::Injected { condition, .. } => Some(condition),
            CellKind::NegativeControl { .. } => Some(Condition::Ew1TpStraggler),
            CellKind::Healthy { .. } => None,
        }
    }
}

#[derive(Debug, Clone)]
struct Cell {
    kind: CellKind,
    cfg: ScenarioCfg,
}

/// Compact per-cell result shipped back from a worker thread.
#[derive(Debug)]
struct CellOutcome {
    kind: CellKind,
    /// Post-injection detection counts (full-run counts for healthy cells),
    /// sorted by condition for deterministic aggregation.
    detections: Vec<(Condition, u64)>,
    detected: bool,
    latency_ns: Option<u64>,
    windows: u64,
    invisible_dropped: u64,
    sw_noticed: bool,
    sw_identified: bool,
    attribution_ok: bool,
}

/// Enumerate the matrix cells in deterministic order: healthy controls,
/// then ALL_CONDITIONS × replicates, then negative controls.
fn cells(mc: &MatrixConfig) -> Vec<Cell> {
    let reps = mc.replicates.max(1);
    let mut v = Vec::with_capacity(reps * (ALL_CONDITIONS.len() + 2));
    for rep in 0..reps {
        let mut cfg = mc.base.clone();
        cfg.seed = mc.base.seed.wrapping_add(rep as u64);
        cfg.inject = None;
        v.push(Cell { kind: CellKind::Healthy { rep }, cfg });
    }
    for c in ALL_CONDITIONS {
        for rep in 0..reps {
            let mut cfg = shaped_cfg(c, &mc.base);
            cfg.seed = mc.base.seed.wrapping_add(rep as u64);
            cfg.inject = Some((c, inject_time(&cfg)));
            v.push(Cell { kind: CellKind::Injected { condition: c, rep }, cfg });
        }
    }
    if mc.negative_control {
        for rep in 0..reps {
            let mut cfg = mc.base.clone();
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.nodes_per_stage = 1; // TP stays intra-node on NVLink
            cfg.cluster.pp_degree = 2;
            cfg.seed = mc.base.seed.wrapping_add(rep as u64);
            cfg.inject = Some((Condition::Ew1TpStraggler, inject_time(&cfg)));
            v.push(Cell { kind: CellKind::NegativeControl { rep }, cfg });
        }
    }
    v
}

fn run_cell(cell: &Cell) -> CellOutcome {
    let res = Scenario::new(cell.cfg.clone()).run();
    let injected = cell.kind.injected();
    // An injection cell whose injection never landed (duration too short)
    // counts as a hard miss rather than crediting pre-injection firings.
    let missed_injection = injected.is_some() && res.injected_at.is_none();
    let t0 = res.injected_at.unwrap_or(SimTime::ZERO);
    let mut counts: BTreeMap<Condition, u64> = BTreeMap::new();
    if !missed_injection {
        for d in &res.detections {
            if d.at >= t0 {
                *counts.entry(d.condition).or_insert(0) += 1;
            }
        }
    }
    let detected = injected
        .map(|c| counts.get(&c).copied().unwrap_or(0) > 0)
        .unwrap_or(false);
    let latency_ns = injected.and_then(|c| res.detection_latency(c)).map(|d| d.ns());
    let sw_fired: Vec<swdet::SwAlarm> = if missed_injection {
        Vec::new()
    } else {
        res.sw_alarm_log.iter().filter(|a| a.at >= t0).map(|a| a.alarm).collect()
    };
    let sw_noticed = injected.is_some() && !sw_fired.is_empty();
    let sw_identified = match injected {
        Some(c) => sw_fired.iter().any(|a| swdet::identifies(*a).contains(&c)),
        None => false,
    };
    // An attribution counts only when it both lands in the expected cause
    // class AND names the injected condition — a cross-talk detection with a
    // coincidentally matching class must not inflate accuracy.
    let attribution_ok = match injected {
        Some(c) if !missed_injection => {
            let expected = expected_cause_classes(c);
            res.attributions.iter().any(|a| {
                expected.contains(&cause_class(&a.cause)) && a.conditions.contains(&c)
            })
        }
        _ => false,
    };
    CellOutcome {
        kind: cell.kind,
        detections: counts.into_iter().collect(),
        detected,
        latency_ns,
        windows: res.windows,
        invisible_dropped: res.dpu_invisible_dropped,
        sw_noticed,
        sw_identified,
        attribution_ok,
    }
}

/// §4.3 negative-control aggregate.
#[derive(Debug, Clone)]
pub struct NegativeControlReport {
    pub runs: u64,
    /// EW1 firings after injection — must be zero (NVLink blindness).
    pub ew1_detections: u64,
    /// Events rejected at the visibility boundary across control runs.
    pub invisible_dropped: u64,
}

/// Everything a matrix run produces.
#[derive(Debug)]
pub struct MatrixReport {
    /// One scorecard per condition, ALL_CONDITIONS order.
    pub scorecards: Vec<Scorecard>,
    pub confusion: ConfusionMatrix,
    pub replicates: u64,
    pub base_seed: u64,
    pub window_ns: u64,
    pub healthy_runs: u64,
    pub healthy_windows: u64,
    pub healthy_false_alarms: u64,
    pub negative_control: Option<NegativeControlReport>,
    pub cells_run: usize,
    pub threads_used: usize,
}

/// Execute the full matrix in parallel and aggregate the scorecards.
pub fn run_matrix(mc: &MatrixConfig) -> MatrixReport {
    let cells = cells(mc);
    let threads_used = resolve_threads(mc.threads, cells.len());
    let outcomes = parallel_map(&cells, mc.threads, run_cell);
    aggregate(mc, outcomes, cells.len(), threads_used)
}

fn aggregate(
    mc: &MatrixConfig,
    outcomes: Vec<CellOutcome>,
    cells_run: usize,
    threads_used: usize,
) -> MatrixReport {
    let mut confusion = ConfusionMatrix::new();
    let mut cards: BTreeMap<Condition, Scorecard> =
        ALL_CONDITIONS.iter().map(|&c| (c, Scorecard::new(c))).collect();
    let mut healthy_runs = 0u64;
    let mut healthy_windows = 0u64;
    let mut healthy_false_alarms = 0u64;
    let mut neg = NegativeControlReport { runs: 0, ew1_detections: 0, invisible_dropped: 0 };

    for out in &outcomes {
        match out.kind {
            CellKind::Healthy { .. } => {
                healthy_runs += 1;
                healthy_windows += out.windows;
                confusion.record_healthy_counts(&out.detections, out.windows);
                for (c, n) in &out.detections {
                    healthy_false_alarms += *n;
                    cards.get_mut(c).unwrap().healthy_false_alarms += *n;
                }
            }
            CellKind::Injected { condition, .. } => {
                confusion.record_counts(condition, &out.detections, out.detected);
                let card = cards.get_mut(&condition).unwrap();
                card.runs += 1;
                if out.detected {
                    card.detected_runs += 1;
                }
                if let Some(lat) = out.latency_ns {
                    card.latency_ns.push(lat as f64);
                }
                if out.sw_noticed {
                    card.sw_noticed_runs += 1;
                }
                if out.sw_identified {
                    card.sw_identified_runs += 1;
                }
                if out.attribution_ok {
                    card.attribution_hits += 1;
                }
                for (c, n) in &out.detections {
                    if *c == condition {
                        card.self_firings += *n;
                    } else {
                        card.other_firings += *n;
                    }
                }
                // Cross-talk is a false positive *for the fired condition*:
                // it fired during somebody else's injection.
                for (c, _) in &out.detections {
                    if *c != condition {
                        cards.get_mut(c).unwrap().false_positive_runs += 1;
                    }
                }
            }
            CellKind::NegativeControl { .. } => {
                neg.runs += 1;
                neg.invisible_dropped += out.invisible_dropped;
                neg.ew1_detections += out
                    .detections
                    .iter()
                    .filter(|(c, _)| *c == Condition::Ew1TpStraggler)
                    .map(|(_, n)| *n)
                    .sum::<u64>();
            }
        }
    }

    let total_injected_runs: u64 = cards.values().map(|s| s.runs).sum();
    for card in cards.values_mut() {
        card.other_condition_runs = total_injected_runs - card.runs;
        card.diagonal_precision = confusion.diagonal_precision(card.condition);
    }
    let scorecards: Vec<Scorecard> =
        ALL_CONDITIONS.iter().map(|c| cards.remove(c).unwrap()).collect();

    MatrixReport {
        scorecards,
        confusion,
        replicates: mc.replicates.max(1) as u64,
        base_seed: mc.base.seed,
        window_ns: mc.base.window.ns(),
        healthy_runs,
        healthy_windows,
        healthy_false_alarms,
        negative_control: if mc.negative_control { Some(neg) } else { None },
        cells_run,
        threads_used,
    }
}

impl MatrixReport {
    /// Conditions identified in at least one replicate.
    pub fn detected_count(&self) -> usize {
        self.scorecards.iter().filter(|s| s.identified()).count()
    }

    /// Mean per-condition recall.
    pub fn macro_recall(&self) -> f64 {
        if self.scorecards.is_empty() {
            return 0.0;
        }
        self.scorecards.iter().map(|s| s.recall()).sum::<f64>() / self.scorecards.len() as f64
    }

    /// Paper-style scorecard + confusion tables.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new("E5 — detection-quality scorecard (28 conditions × replicates)")
            .header(&[
                "id",
                "recall",
                "ttd p50",
                "ttd (win)",
                "fp rate",
                "diag prec",
                "attr acc",
                "SW id/not",
                "coverage",
                "directive",
            ]);
        for s in &self.scorecards {
            let (ttd, ttd_win) = if s.latency_ns.is_empty() {
                ("-".to_string(), "-".to_string())
            } else {
                (
                    fmt_ns(s.latency_ns.p50()),
                    format!("{:.1}", s.latency_ns.p50() / self.window_ns.max(1) as f64),
                )
            };
            t.row(vec![
                s.condition.id().to_string(),
                format!("{}/{}", s.detected_runs, s.runs),
                ttd,
                ttd_win,
                format!("{:.3}", s.false_positive_rate()),
                format!("{:.2}", s.diagonal_precision),
                format!("{:.0}%", s.attribution_accuracy() * 100.0),
                format!("{}/{}", s.sw_identified_runs, s.sw_noticed_runs),
                s.coverage_delta().to_string(),
                format!("{:?}", runbook::entry(s.condition).directive),
            ]);
        }
        let mut out = t.render();
        out.push_str(&self.confusion.render());
        out
    }

    /// One-paragraph human summary (incl. the §4.3 control verdict).
    pub fn summary_line(&self) -> String {
        let sw_not = self.scorecards.iter().filter(|s| s.sw_noticed_runs > 0).count();
        let sw_id = self.scorecards.iter().filter(|s| s.sw_identified_runs > 0).count();
        let mut s = format!(
            "DPU identified {}/{} (macro recall {:.2}); SW noticed {}/{} but identified {}/{}; \
             healthy false alarms {} over {} windows ({} runs)",
            self.detected_count(),
            self.scorecards.len(),
            self.macro_recall(),
            sw_not,
            self.scorecards.len(),
            sw_id,
            self.scorecards.len(),
            self.healthy_false_alarms,
            self.healthy_windows,
            self.healthy_runs,
        );
        if let Some(nc) = &self.negative_control {
            s.push_str(&format!(
                "\n4.3 negative control (TP on NVLink, straggler injected): EW1 detections = {} \
                 across {} runs (expected 0 — NVLink collectives bypass the DPU; {} invisible \
                 events dropped)",
                nc.ew1_detections, nc.runs, nc.invisible_dropped
            ));
        }
        s
    }

    /// Deterministic JSON scorecard: same config + seed ⇒ byte-identical
    /// output, independent of worker-thread count. Wallclock and thread
    /// metadata are deliberately excluded.
    pub fn to_json(&self) -> Json {
        let mut conds = Json::arr();
        for s in &self.scorecards {
            let latency = if s.latency_ns.is_empty() {
                Json::Null
            } else {
                Json::obj()
                    .set("min_ns", s.latency_ns.min())
                    .set("p50_ns", s.latency_ns.p50())
                    .set("max_ns", s.latency_ns.max())
            };
            conds.push(
                Json::obj()
                    .set("id", s.condition.id())
                    .set("table", s.condition.table())
                    .set("runs", s.runs)
                    .set("detected_runs", s.detected_runs)
                    .set("recall", s.recall())
                    .set("latency", latency)
                    .set("self_firings", s.self_firings)
                    .set("other_firings", s.other_firings)
                    .set("diagonal_precision", s.diagonal_precision)
                    .set("false_positive_runs", s.false_positive_runs)
                    .set("other_condition_runs", s.other_condition_runs)
                    .set("false_positive_rate", s.false_positive_rate())
                    .set("healthy_false_alarms", s.healthy_false_alarms)
                    .set("attribution_accuracy", s.attribution_accuracy())
                    .set("sw_noticed_runs", s.sw_noticed_runs)
                    .set("sw_identified_runs", s.sw_identified_runs)
                    .set("coverage", s.coverage_delta())
                    .set("directive", format!("{:?}", runbook::entry(s.condition).directive)),
            );
        }
        let negative = match &self.negative_control {
            None => Json::Null,
            Some(nc) => Json::obj()
                .set("runs", nc.runs)
                .set("ew1_detections", nc.ew1_detections)
                .set("invisible_dropped", nc.invisible_dropped),
        };
        Json::obj()
            .set("schema", "dpulens.matrix.v1")
            .set("replicates", self.replicates)
            .set("base_seed", self.base_seed)
            .set("window_ns", self.window_ns)
            .set("detected", self.detected_count())
            .set("macro_recall", self.macro_recall())
            .set(
                "healthy",
                Json::obj()
                    .set("runs", self.healthy_runs)
                    .set("windows", self.healthy_windows)
                    .set("false_alarms", self.healthy_false_alarms),
            )
            .set("negative_control", negative)
            .set("conditions", conds)
    }
}

/// Parallel all-28 runbook sweep: the three-phase condition experiment
/// (healthy / injected / optionally mitigated) per condition, each on its
/// shaped config. The engine behind `dpulens sweep` and the quick-look
/// example; returns reports in ALL_CONDITIONS order.
pub fn run_sweep(base: &ScenarioCfg, mitigate: bool, threads: usize) -> Vec<ConditionReport> {
    let conds: Vec<Condition> = ALL_CONDITIONS.to_vec();
    parallel_map(&conds, threads, |&c| {
        let cfg = shaped_cfg(c, base);
        condition_experiment(c, &cfg, mitigate)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_enumerate_deterministically() {
        let mc = MatrixConfig { replicates: 2, ..MatrixConfig::fast() };
        let a = cells(&mc);
        let b = cells(&mc);
        assert_eq!(a.len(), 2 * (1 + ALL_CONDITIONS.len() + 1));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.cfg.seed, y.cfg.seed);
        }
        assert!(matches!(a[0].kind, CellKind::Healthy { rep: 0 }));
        assert!(matches!(a.last().unwrap().kind, CellKind::NegativeControl { rep: 1 }));
        // Replicate 0 keeps the base seed: it reproduces the serial bench.
        assert_eq!(a[0].cfg.seed, mc.base.seed);
    }

    #[test]
    fn negative_control_can_be_disabled() {
        let mut mc = MatrixConfig::fast();
        mc.negative_control = false;
        let v = cells(&mc);
        assert_eq!(v.len(), 1 + ALL_CONDITIONS.len());
        assert!(v.iter().all(|c| !matches!(c.kind, CellKind::NegativeControl { .. })));
    }

    #[test]
    fn expected_classes_cover_all_conditions() {
        for c in ALL_CONDITIONS {
            assert!(!expected_cause_classes(c).is_empty(), "{c:?}");
        }
        assert!(expected_cause_classes(Condition::Pc8HostCpuBottleneck).contains(&"host"));
        assert!(expected_cause_classes(Condition::Ew1TpStraggler).contains(&"network"));
        assert!(expected_cause_classes(Condition::Ns8EarlyCompletion).contains(&"workload"));
    }

    #[test]
    fn shaped_cfg_promotes_compute_profiles() {
        let base = standard_cfg();
        assert_eq!(shaped_cfg(Condition::Ew1TpStraggler, &base).engine.profile.name, "7b");
        assert_eq!(shaped_cfg(Condition::Ns4IngressRetx, &base).engine.profile.name, "small");
        // Shaping never touches the seed or the injection slot.
        let s = shaped_cfg(Condition::Ew2PpBubble, &base);
        assert_eq!(s.seed, base.seed);
        assert!(s.inject.is_none());
    }

    #[test]
    fn cause_class_covers_every_variant() {
        use crate::ids::NodeId;
        assert_eq!(cause_class(&RootCause::HostLocal(NodeId(0))), "host");
        assert_eq!(cause_class(&RootCause::GpuSide(NodeId(1))), "gpu");
        assert_eq!(cause_class(&RootCause::NetworkSide), "network");
        assert_eq!(cause_class(&RootCause::WorkloadShape), "workload");
        assert_eq!(cause_class(&RootCause::ClientSide), "client");
    }
}
