//! The scenario-matrix runner: the paper's central evaluation (§§4.1-4.3,
//! Tables 3a-c) as a first-class, parallel, machine-readable subsystem.
//!
//! One matrix run executes, fanned out over scoped worker threads
//! (`util::par` — the sim is deterministic and every cell is independent):
//!
//! * `replicates` healthy control runs (false-alarm floor),
//! * `replicates` injected runs per runbook condition (28 × R cells, each
//!   with the per-condition scenario shaping the detection benches proved
//!   out), and
//! * `replicates` §4.3 negative-control runs (TP pinned to NVLink via
//!   single-node stages: an injected GPU straggler must stay invisible).
//!   These run on a 2-replica world and victimize replica 1, so the matrix
//!   also exercises non-zero-replica victim selection.
//!
//! Replicates vary only the scenario seed (`base.seed + rep`), so replicate
//! 0 reproduces the serial bench bit-for-bit. The aggregate is a
//! per-condition [`Scorecard`] plus the full injection × detection
//! [`ConfusionMatrix`], assembled into a [`MatrixReport`] (rendering and
//! JSON live in `coordinator::report`). Two runs with the same config
//! produce byte-identical JSON regardless of thread count.

use std::collections::BTreeMap;

use crate::coordinator::experiment::{
    cause_class, condition_experiment, expected_cause_classes, inject_time, shaped_cfg,
    standard_cfg, ConditionReport,
};
use crate::coordinator::scenario::{RunResult, ScenarioCfg};
use crate::coordinator::snapshot;
use crate::dpu::detectors::{Condition, ALL_CONDITIONS};
use crate::dpu::swdet;
use crate::engine::preset;
use crate::metrics::{ConfusionMatrix, Scorecard};
use crate::sim::SimTime;
use crate::util::par::{parallel_map, resolve_threads};

pub use crate::coordinator::report::{MatrixReport, NegativeControlReport};

/// Matrix-run configuration.
#[derive(Debug, Clone)]
pub struct MatrixConfig {
    /// Base scenario every cell derives from (duration, windows, seed...).
    pub base: ScenarioCfg,
    /// Seed-replicated runs per condition (seeds `base.seed + 0..R`).
    pub replicates: usize,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Include the §4.3 NVLink-blindness negative control cells.
    pub negative_control: bool,
    /// Force every cell to simulate from scratch instead of forking shared
    /// pre-injection prefixes (`--no-reuse`; equivalence debugging).
    pub no_reuse: bool,
}

impl Default for MatrixConfig {
    fn default() -> Self {
        MatrixConfig {
            base: standard_cfg(),
            replicates: 3,
            threads: 0,
            negative_control: true,
            no_reuse: false,
        }
    }
}

impl MatrixConfig {
    /// Single replicate on the standard shaped configs — the fastest
    /// configuration the full 28/28 diagonal is still proven on.
    pub fn fast() -> Self {
        MatrixConfig { replicates: 1, ..MatrixConfig::default() }
    }
}

/// One cell of the matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// No injection: false-alarm floor.
    Healthy { rep: usize },
    /// Condition injected after calibration.
    Injected { condition: Condition, rep: usize },
    /// §4.3 control: EW1 straggler with TP pinned to NVLink (invisible).
    NegativeControl { rep: usize },
}

impl CellKind {
    fn injected(self) -> Option<Condition> {
        match self {
            CellKind::Injected { condition, .. } => Some(condition),
            CellKind::NegativeControl { .. } => Some(Condition::Ew1TpStraggler),
            CellKind::Healthy { .. } => None,
        }
    }
}

#[derive(Debug, Clone)]
struct Cell {
    kind: CellKind,
    cfg: ScenarioCfg,
}

/// Compact per-cell result shipped back from a worker thread.
#[derive(Debug)]
struct CellOutcome {
    kind: CellKind,
    /// Post-injection detection counts (full-run counts for healthy cells),
    /// sorted by condition for deterministic aggregation.
    detections: Vec<(Condition, u64)>,
    detected: bool,
    latency_ns: Option<u64>,
    windows: u64,
    /// Telemetry events the cell's pipeline delivered (perf accounting).
    events: u64,
    invisible_dropped: u64,
    sw_noticed: bool,
    sw_identified: bool,
    attribution_ok: bool,
}

/// Enumerate the matrix cells in deterministic order: healthy controls,
/// then ALL_CONDITIONS × replicates, then negative controls.
fn cells(mc: &MatrixConfig) -> Vec<Cell> {
    let reps = mc.replicates.max(1);
    let mut v = Vec::with_capacity(reps * (ALL_CONDITIONS.len() + 2));
    for rep in 0..reps {
        let mut cfg = mc.base.clone();
        cfg.seed = mc.base.seed.wrapping_add(rep as u64);
        cfg.inject = None;
        v.push(Cell { kind: CellKind::Healthy { rep }, cfg });
    }
    for c in ALL_CONDITIONS {
        for rep in 0..reps {
            let mut cfg = shaped_cfg(c, &mc.base);
            cfg.seed = mc.base.seed.wrapping_add(rep as u64);
            cfg.inject = Some((c, inject_time(&cfg)));
            v.push(Cell { kind: CellKind::Injected { condition: c, rep }, cfg });
        }
    }
    if mc.negative_control {
        for rep in 0..reps {
            let mut cfg = mc.base.clone();
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.nodes_per_stage = 1; // TP stays intra-node on NVLink
            cfg.cluster.pp_degree = 2;
            // Two replicas here: victimize the non-zero one, proving the
            // replica-aware target selection end to end.
            cfg.victim_replica = 1;
            cfg.seed = mc.base.seed.wrapping_add(rep as u64);
            cfg.inject = Some((Condition::Ew1TpStraggler, inject_time(&cfg)));
            v.push(Cell { kind: CellKind::NegativeControl { rep }, cfg });
        }
    }
    v
}

/// Score one executed cell. Cells run through the snapshot runner (shared
/// pre-injection prefixes fork instead of re-simulating); the scoring is
/// identical either way because forked results are byte-identical.
fn score_cell(kind: CellKind, res: &RunResult) -> CellOutcome {
    let injected = kind.injected();
    // An injection cell whose injection never landed (duration too short)
    // counts as a hard miss rather than crediting pre-injection firings.
    let missed_injection = injected.is_some() && res.injected_at.is_none();
    let t0 = res.injected_at.unwrap_or(SimTime::ZERO);
    let mut counts: BTreeMap<Condition, u64> = BTreeMap::new();
    if !missed_injection {
        for d in &res.detections {
            if d.at >= t0 {
                *counts.entry(d.condition).or_insert(0) += 1;
            }
        }
    }
    let detected = injected
        .map(|c| counts.get(&c).copied().unwrap_or(0) > 0)
        .unwrap_or(false);
    let latency_ns = injected.and_then(|c| res.detection_latency(c)).map(|d| d.ns());
    let sw_fired: Vec<swdet::SwAlarm> = if missed_injection {
        Vec::new()
    } else {
        res.sw_alarm_log.iter().filter(|a| a.at >= t0).map(|a| a.alarm).collect()
    };
    let sw_noticed = injected.is_some() && !sw_fired.is_empty();
    let sw_identified = match injected {
        Some(c) => sw_fired.iter().any(|a| swdet::identifies(*a).contains(&c)),
        None => false,
    };
    // An attribution counts only when it both lands in the expected cause
    // class AND names the injected condition — a cross-talk detection with a
    // coincidentally matching class must not inflate accuracy.
    let attribution_ok = match injected {
        Some(c) if !missed_injection => {
            let expected = expected_cause_classes(c);
            res.attributions.iter().any(|a| {
                expected.contains(&cause_class(&a.cause)) && a.conditions.contains(&c)
            })
        }
        _ => false,
    };
    CellOutcome {
        kind,
        detections: counts.into_iter().collect(),
        detected,
        latency_ns,
        windows: res.windows,
        events: res.telemetry_published,
        invisible_dropped: res.dpu_invisible_dropped,
        sw_noticed,
        sw_identified,
        attribution_ok,
    }
}

/// Execute the full matrix in parallel and aggregate the scorecards.
/// Wall-clock and events/sec land in the report's perf fields (excluded
/// from the deterministic JSON; see `MatrixReport::to_json`).
pub fn run_matrix(mc: &MatrixConfig) -> MatrixReport {
    let cells = cells(mc);
    let n_cells = cells.len();
    let threads_used = resolve_threads(mc.threads, n_cells);
    let timer = crate::util::perf::PhaseTimer::start();
    // Cells are consumed: kinds stay behind for scoring, configs move into
    // the snapshot runner (no per-cell ScenarioCfg deep-clone).
    let (kinds, cfgs): (Vec<CellKind>, Vec<ScenarioCfg>) =
        cells.into_iter().map(|c| (c.kind, c.cfg)).unzip();
    let (results, reuse) = snapshot::run_all(cfgs, mc.threads, mc.no_reuse);
    let outcomes: Vec<CellOutcome> = kinds
        .into_iter()
        .zip(results.iter())
        .map(|(kind, res)| score_cell(kind, res))
        .collect();
    let elapsed_ms = timer.total_ms();
    aggregate(mc, outcomes, reuse, n_cells, threads_used, elapsed_ms)
}

fn aggregate(
    mc: &MatrixConfig,
    outcomes: Vec<CellOutcome>,
    reuse: snapshot::ReuseStats,
    cells_run: usize,
    threads_used: usize,
    elapsed_ms: f64,
) -> MatrixReport {
    let mut confusion = ConfusionMatrix::new();
    let mut cards: BTreeMap<Condition, Scorecard> =
        ALL_CONDITIONS.iter().map(|&c| (c, Scorecard::new(c))).collect();
    let mut healthy_runs = 0u64;
    let mut healthy_windows = 0u64;
    let mut healthy_false_alarms = 0u64;
    let mut neg = NegativeControlReport { runs: 0, ew1_detections: 0, invisible_dropped: 0 };

    for out in &outcomes {
        match out.kind {
            CellKind::Healthy { .. } => {
                healthy_runs += 1;
                healthy_windows += out.windows;
                confusion.record_healthy_counts(&out.detections, out.windows);
                for (c, n) in &out.detections {
                    healthy_false_alarms += *n;
                    // Conditions outside the 28-card diagonal (the DP fleet
                    // family) are counted in the floor but carry no card.
                    if let Some(card) = cards.get_mut(c) {
                        card.healthy_false_alarms += *n;
                    }
                }
            }
            CellKind::Injected { condition, .. } => {
                confusion.record_counts(condition, &out.detections, out.detected);
                let card = cards.get_mut(&condition).unwrap();
                card.runs += 1;
                if out.detected {
                    card.detected_runs += 1;
                }
                if let Some(lat) = out.latency_ns {
                    card.latency_ns.push(lat as f64);
                }
                if out.sw_noticed {
                    card.sw_noticed_runs += 1;
                }
                if out.sw_identified {
                    card.sw_identified_runs += 1;
                }
                if out.attribution_ok {
                    card.attribution_hits += 1;
                }
                for (c, n) in &out.detections {
                    if *c == condition {
                        card.self_firings += *n;
                    } else {
                        card.other_firings += *n;
                    }
                }
                // Cross-talk is a false positive *for the fired condition*:
                // it fired during somebody else's injection.
                for (c, _) in &out.detections {
                    if *c != condition {
                        if let Some(other) = cards.get_mut(c) {
                            other.false_positive_runs += 1;
                        }
                    }
                }
            }
            CellKind::NegativeControl { .. } => {
                neg.runs += 1;
                neg.invisible_dropped += out.invisible_dropped;
                neg.ew1_detections += out
                    .detections
                    .iter()
                    .filter(|(c, _)| *c == Condition::Ew1TpStraggler)
                    .map(|(_, n)| *n)
                    .sum::<u64>();
            }
        }
    }

    let total_injected_runs: u64 = cards.values().map(|s| s.runs).sum();
    for card in cards.values_mut() {
        card.other_condition_runs = total_injected_runs - card.runs;
        card.diagonal_precision = confusion.diagonal_precision(card.condition);
    }
    let scorecards: Vec<Scorecard> =
        ALL_CONDITIONS.iter().map(|c| cards.remove(c).unwrap()).collect();
    let events_total: u64 = outcomes.iter().map(|o| o.events).sum();

    MatrixReport {
        scorecards,
        confusion,
        replicates: mc.replicates.max(1) as u64,
        base_seed: mc.base.seed,
        window_ns: mc.base.window.ns(),
        healthy_runs,
        healthy_windows,
        healthy_false_alarms,
        negative_control: if mc.negative_control { Some(neg) } else { None },
        cells_run,
        threads_used,
        elapsed_ms,
        events_total,
        reuse,
    }
}

/// Parallel all-28 runbook sweep: the three-phase condition experiment
/// (healthy / injected / optionally mitigated) per condition, each on its
/// shaped config. The engine behind `dpulens sweep` and the quick-look
/// example; returns reports in ALL_CONDITIONS order.
pub fn run_sweep(base: &ScenarioCfg, mitigate: bool, threads: usize) -> Vec<ConditionReport> {
    let conds: Vec<Condition> = ALL_CONDITIONS.to_vec();
    parallel_map(&conds, threads, |&c| {
        let cfg = shaped_cfg(c, base);
        condition_experiment(c, &cfg, mitigate)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_enumerate_deterministically() {
        let mc = MatrixConfig { replicates: 2, ..MatrixConfig::fast() };
        let a = cells(&mc);
        let b = cells(&mc);
        assert_eq!(a.len(), 2 * (1 + ALL_CONDITIONS.len() + 1));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.cfg.seed, y.cfg.seed);
        }
        assert!(matches!(a[0].kind, CellKind::Healthy { rep: 0 }));
        assert!(matches!(a.last().unwrap().kind, CellKind::NegativeControl { rep: 1 }));
        // Replicate 0 keeps the base seed: it reproduces the serial bench.
        assert_eq!(a[0].cfg.seed, mc.base.seed);
        // The negative control victimizes a non-zero replica.
        assert_eq!(a.last().unwrap().cfg.victim_replica, 1);
    }

    #[test]
    fn negative_control_can_be_disabled() {
        let mut mc = MatrixConfig::fast();
        mc.negative_control = false;
        let v = cells(&mc);
        assert_eq!(v.len(), 1 + ALL_CONDITIONS.len());
        assert!(v.iter().all(|c| !matches!(c.kind, CellKind::NegativeControl { .. })));
    }
}
