//! Artifact discovery + validation: MANIFEST.txt, weights.bin, golden.txt.
//!
//! The manifest is the cross-language contract: the Rust side refuses to run
//! against artifacts whose shapes disagree with its expectations.

use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::kv::KvFile;

/// Parsed MANIFEST.txt.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub batch: usize,
    pub detector_windows: usize,
    pub detector_samples: usize,
    pub detector_features: usize,
    /// (name, shape) in weights.bin order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let kv = KvFile::parse(text)?;
        let mut params = Vec::new();
        for p in kv.get_all("param") {
            let (name, dims) = p
                .split_once(':')
                .with_context(|| format!("bad param line {p:?}"))?;
            let shape: Vec<usize> = dims
                .split('x')
                .map(|d| d.parse().with_context(|| format!("bad dim in {p:?}")))
                .collect::<Result<_>>()?;
            params.push((name.to_string(), shape));
        }
        Ok(Manifest {
            preset: kv.require("preset")?.to_string(),
            layers: kv.require_usize("layers")?,
            d_model: kv.require_usize("d_model")?,
            n_heads: kv.require_usize("n_heads")?,
            head_dim: kv.require_usize("head_dim")?,
            ffn: kv.require_usize("ffn")?,
            vocab: kv.require_usize("vocab")?,
            max_seq: kv.require_usize("max_seq")?,
            prefill_len: kv.require_usize("prefill_len")?,
            batch: kv.require_usize("batch")?,
            detector_windows: kv.require_usize("detector_windows")?,
            detector_samples: kv.require_usize("detector_samples")?,
            detector_features: kv.require_usize("detector_features")?,
            params,
        })
    }

    /// KV cache shape `[L, 2, B, H, S_max, Dh]`.
    pub fn kv_dims(&self) -> [usize; 6] {
        [self.layers, 2, self.batch, self.n_heads, self.max_seq, self.head_dim]
    }

    pub fn kv_elems(&self) -> usize {
        self.kv_dims().iter().product()
    }
}

/// A resolved artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

const WEIGHTS_MAGIC: &[u8; 8] = b"DPLW0001";

impl ArtifactSet {
    /// Open and validate an artifact directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactSet> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("MANIFEST.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&text)?;
        for name in ["prefill.hlo.txt", "decode_step.hlo.txt", "detector.hlo.txt", "weights.bin"] {
            if !dir.join(name).exists() {
                bail!("artifact {name} missing from {dir:?}");
            }
        }
        Ok(ArtifactSet { dir, manifest })
    }

    /// Default location: `$DPULENS_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactSet> {
        let dir = std::env::var("DPULENS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Load weights.bin as flat f32 vectors, validated against the manifest.
    pub fn load_weights(&self) -> Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
        let mut f = std::fs::File::open(self.path("weights.bin"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != WEIGHTS_MAGIC {
            bail!("weights.bin bad magic {magic:?}");
        }
        let mut u32buf = [0u8; 4];
        f.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf) as usize;
        if count != self.manifest.params.len() {
            bail!("weights.bin has {count} params, manifest {}", self.manifest.params.len());
        }
        let mut out = Vec::with_capacity(count);
        for (want_name, want_shape) in &self.manifest.params {
            f.read_exact(&mut u32buf)?;
            let nlen = u32::from_le_bytes(u32buf) as usize;
            let mut name_buf = vec![0u8; nlen];
            f.read_exact(&mut name_buf)?;
            let name = String::from_utf8(name_buf)?;
            if &name != want_name {
                bail!("weights order mismatch: got {name}, want {want_name}");
            }
            f.read_exact(&mut u32buf)?;
            let ndim = u32::from_le_bytes(u32buf) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                f.read_exact(&mut u32buf)?;
                shape.push(u32::from_le_bytes(u32buf) as usize);
            }
            if &shape != want_shape {
                bail!("shape mismatch for {name}: {shape:?} vs {want_shape:?}");
            }
            let mut u64buf = [0u8; 8];
            f.read_exact(&mut u64buf)?;
            let nbytes = u64::from_le_bytes(u64buf) as usize;
            let n_elems: usize = shape.iter().product();
            if nbytes != 4 * n_elems {
                bail!("byte count mismatch for {name}");
            }
            let mut data = vec![0u8; nbytes];
            f.read_exact(&mut data)?;
            let floats: Vec<f32> = data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.push((name, shape, floats));
        }
        Ok(out)
    }

    /// Parse golden.txt into (prefill_logits[b][j], greedy_tokens[t][b],
    /// decode_logits[t][b][j]).
    #[allow(clippy::type_complexity)]
    pub fn load_golden(&self) -> Result<(Vec<Vec<f32>>, Vec<Vec<i32>>, Vec<Vec<Vec<f32>>>)> {
        let text = std::fs::read_to_string(self.path("golden.txt"))?;
        let b = self.manifest.batch;
        let mut prefill = vec![vec![0f32; 8]; b];
        let mut tokens: Vec<Vec<i32>> = Vec::new();
        let mut decode: Vec<Vec<Vec<f32>>> = Vec::new();
        for line in text.lines() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.first().copied() {
                Some("prefill_logit") => {
                    let (bi, j, v): (usize, usize, f32) =
                        (parts[1].parse()?, parts[2].parse()?, parts[3].parse()?);
                    prefill[bi][j] = v;
                }
                Some("greedy_token") => {
                    let (t, bi, tok): (usize, usize, i32) =
                        (parts[1].parse()?, parts[2].parse()?, parts[3].parse()?);
                    while tokens.len() <= t {
                        tokens.push(vec![0; b]);
                    }
                    tokens[t][bi] = tok;
                }
                Some("decode_logit") => {
                    let (t, bi, j, v): (usize, usize, usize, f32) = (
                        parts[1].parse()?,
                        parts[2].parse()?,
                        parts[3].parse()?,
                        parts[4].parse()?,
                    );
                    while decode.len() <= t {
                        decode.push(vec![vec![0f32; 8]; b]);
                    }
                    decode[t][bi][j] = v;
                }
                _ => {}
            }
        }
        Ok((prefill, tokens, decode))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = "format=1\npreset=small\nlayers=4\nd_model=256\nn_heads=8\n\
        head_dim=32\nffn=1024\nvocab=2048\nmax_seq=128\nprefill_len=64\nbatch=4\n\
        detector_windows=64\ndetector_samples=256\ndetector_features=8\n\
        param=embed:2048x256\nparam=pos_embed:128x256\n";

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.preset, "small");
        assert_eq!(m.layers, 4);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].1, vec![2048, 256]);
        assert_eq!(m.kv_dims(), [4, 2, 4, 8, 128, 32]);
    }

    #[test]
    fn bad_manifest_rejected() {
        assert!(Manifest::parse("preset=x\n").is_err());
        assert!(Manifest::parse(&MANIFEST.replace("param=embed:2048x256", "param=embed")).is_err());
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(ArtifactSet::open("/nonexistent/dir").is_err());
    }
}
