//! The real compiled transformer: prefill + decode executables with a
//! persistent host-side KV cache and per-slot KV surgery, so the engine's
//! continuous batching works against fixed-shape PJRT executables.

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::engine::exec::ComputeBackend;
use crate::runtime::artifacts::ArtifactSet;

/// A loaded, compiled transformer with serving state.
pub struct TransformerSession {
    prefill_exe: PjRtLoadedExecutable,
    decode_exe: PjRtLoadedExecutable,
    /// Weight literals in param order (shared by both executables).
    weights: Vec<Literal>,
    /// Host copy of the KV cache `[L,2,B,H,S,Dh]` (persistent across calls).
    kv_host: Vec<f32>,
    pub batch: usize,
    pub prefill_len: usize,
    pub max_seq: usize,
    pub vocab: usize,
    layers: usize,
    n_heads: usize,
    head_dim: usize,
    /// Executions performed (metrics).
    pub prefill_calls: u64,
    pub decode_calls: u64,
}

impl std::fmt::Debug for TransformerSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TransformerSession")
            .field("batch", &self.batch)
            .field("prefill_calls", &self.prefill_calls)
            .field("decode_calls", &self.decode_calls)
            .finish()
    }
}

fn compile(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path.to_str().context("bad path")?)?;
    let comp = XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

impl TransformerSession {
    /// Load + compile from an artifact directory.
    pub fn load(client: &PjRtClient, arts: &ArtifactSet) -> Result<Self> {
        let m = &arts.manifest;
        let prefill_exe = compile(client, &arts.path("prefill.hlo.txt"))?;
        let decode_exe = compile(client, &arts.path("decode_step.hlo.txt"))?;
        let mut weights = Vec::new();
        for (name, shape, data) in arts.load_weights()? {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = Literal::vec1(&data)
                .reshape(&dims)
                .with_context(|| format!("reshaping weight {name}"))?;
            weights.push(lit);
        }
        Ok(TransformerSession {
            prefill_exe,
            decode_exe,
            weights,
            kv_host: vec![0f32; m.kv_elems()],
            batch: m.batch,
            prefill_len: m.prefill_len,
            max_seq: m.max_seq,
            vocab: m.vocab,
            layers: m.layers,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            prefill_calls: 0,
            decode_calls: 0,
        })
    }

    fn kv_literal(&self) -> Result<Literal> {
        let dims = [
            self.layers as i64,
            2,
            self.batch as i64,
            self.n_heads as i64,
            self.max_seq as i64,
            self.head_dim as i64,
        ];
        Ok(Literal::vec1(&self.kv_host).reshape(&dims)?)
    }

    /// Prefill a full padded block. `tokens` is `[B][S0]`, `lens` `[B]`.
    /// Returns per-sequence logits `[B][V]` and replaces the WHOLE KV cache.
    pub fn prefill_block(&mut self, tokens: &[Vec<i32>], lens: &[i32]) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.batch || lens.len() != self.batch {
            bail!("prefill batch mismatch: {} vs {}", tokens.len(), self.batch);
        }
        let flat: Vec<i32> = tokens.iter().flat_map(|row| {
            debug_assert_eq!(row.len(), self.prefill_len);
            row.iter().copied()
        }).collect();
        let tok_lit =
            Literal::vec1(&flat).reshape(&[self.batch as i64, self.prefill_len as i64])?;
        let lens_lit = Literal::vec1(lens);
        let mut args: Vec<&Literal> = vec![&tok_lit, &lens_lit];
        args.extend(self.weights.iter());
        let result = self.prefill_exe.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits, kv) = result.to_tuple2()?;
        self.kv_host = kv.to_vec::<f32>()?;
        self.prefill_calls += 1;
        let flat_logits = logits.to_vec::<f32>()?;
        Ok(flat_logits.chunks(self.vocab).map(|c| c.to_vec()).collect())
    }

    /// Prefill new sequences into specific slots WITHOUT disturbing other
    /// slots' KV: runs a full prefill block (pad slots get a dummy prompt),
    /// then splices only the named slots' KV into the persistent cache.
    pub fn prefill_slots(
        &mut self,
        slots: &[usize],
        prompts: &[&[i32]],
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(slots.len(), prompts.len());
        let mut tokens = vec![vec![0i32; self.prefill_len]; self.batch];
        let mut lens = vec![1i32; self.batch];
        for (&slot, prompt) in slots.iter().zip(prompts) {
            let n = prompt.len().min(self.prefill_len).max(1);
            tokens[slot][..n].copy_from_slice(&prompt[..n]);
            lens[slot] = n as i32;
        }
        let keep = self.kv_host.clone();
        let logits = self.prefill_block(&tokens, &lens)?;
        // Splice: restore every slot that was NOT prefilled from the saved
        // cache (prefill_block overwrote everything).
        let fresh = std::mem::replace(&mut self.kv_host, keep);
        for &slot in slots {
            self.copy_slot(&fresh, slot);
        }
        Ok(slots.iter().map(|&s| logits[s].clone()).collect())
    }

    /// Copy one batch slot's KV from `src` into the persistent cache.
    fn copy_slot(&mut self, src: &[f32], slot: usize) {
        let block = self.n_heads * self.max_seq * self.head_dim; // [H,S,Dh]
        let per_lkv = self.batch * block; // [B,H,S,Dh]
        for lkv in 0..self.layers * 2 {
            let off = lkv * per_lkv + slot * block;
            self.kv_host[off..off + block].copy_from_slice(&src[off..off + block]);
        }
    }

    /// One decode step over all slots. `tokens`/`positions` are full-batch
    /// (`[B]`); inactive slots should pass token 0 / position 0 (their KV
    /// slot gets scratch writes at position 0, overwritten at next prefill).
    pub fn decode_step(&mut self, tokens: &[i32], positions: &[i32]) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.batch || positions.len() != self.batch {
            bail!("decode batch mismatch");
        }
        let tok_lit = Literal::vec1(tokens);
        let pos_lit = Literal::vec1(positions);
        let kv_lit = self.kv_literal()?;
        let mut args: Vec<&Literal> = vec![&tok_lit, &pos_lit, &kv_lit];
        args.extend(self.weights.iter());
        let result = self.decode_exe.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits, kv) = result.to_tuple2()?;
        self.kv_host = kv.to_vec::<f32>()?;
        self.decode_calls += 1;
        let flat = logits.to_vec::<f32>()?;
        Ok(flat.chunks(self.vocab).map(|c| c.to_vec()).collect())
    }

    /// Greedy argmax over logits.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }
}

impl ComputeBackend for TransformerSession {
    fn prefill(&mut self, slots: &[usize], prompts: &[&[i32]]) -> Vec<i32> {
        let logits = self
            .prefill_slots(slots, prompts)
            .expect("PJRT prefill failed");
        logits.iter().map(|l| Self::argmax(l)).collect()
    }

    fn decode_into(
        &mut self,
        slots: &[usize],
        last_tokens: &[i32],
        positions: &[u32],
        out: &mut Vec<i32>,
    ) {
        // The real backend allocates internally (device transfers dwarf
        // it); only the output buffer is the caller's.
        let mut toks = vec![0i32; self.batch];
        let mut pos = vec![0i32; self.batch];
        for (i, &slot) in slots.iter().enumerate() {
            toks[slot] = last_tokens[i];
            pos[slot] = (positions[i] as i32).min(self.max_seq as i32 - 1);
        }
        let logits = self.decode_step(&toks, &pos).expect("PJRT decode failed");
        out.clear();
        out.extend(slots.iter().map(|&s| Self::argmax(&logits[s])));
    }

    fn is_real(&self) -> bool {
        true
    }
}
