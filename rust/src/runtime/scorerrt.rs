//! The compiled Pallas telemetry scorer (`artifacts/detector.hlo.txt`) as a
//! `dpu::ScorerBackend` — the "DPU-offloaded scoring" path.

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::dpu::scorer::{ScorerBackend, N_FEATURES};
use crate::runtime::artifacts::ArtifactSet;

pub struct CompiledScorer {
    exe: PjRtLoadedExecutable,
    pub windows: usize,
    pub samples: usize,
    pub calls: u64,
}

impl std::fmt::Debug for CompiledScorer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledScorer")
            .field("windows", &self.windows)
            .field("samples", &self.samples)
            .finish()
    }
}

impl CompiledScorer {
    pub fn load(client: &PjRtClient, arts: &ArtifactSet) -> Result<Self> {
        let path = arts.path("detector.hlo.txt");
        let proto = HloModuleProto::from_text_file(path.to_str().context("bad path")?)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(CompiledScorer {
            exe,
            windows: arts.manifest.detector_windows,
            samples: arts.manifest.detector_samples,
            calls: 0,
        })
    }

    /// Run one fixed-shape scoring call: `[W,N]` windows + `[W,2]` baseline.
    pub fn score_block(
        &mut self,
        windows_flat: &[f32],
        baseline_flat: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let w = self.windows as i64;
        let n = self.samples as i64;
        let win = Literal::vec1(windows_flat).reshape(&[w, n])?;
        let base = Literal::vec1(baseline_flat).reshape(&[w, 2])?;
        let result = self.exe.execute::<&Literal>(&[&win, &base])?[0][0].to_literal_sync()?;
        let (feats, z) = result.to_tuple2()?;
        self.calls += 1;
        Ok((feats.to_vec::<f32>()?, z.to_vec::<f32>()?))
    }
}

impl ScorerBackend for CompiledScorer {
    fn score(
        &mut self,
        windows: &[Vec<f32>],
        baseline: &[(f32, f32)],
    ) -> (Vec<[f32; N_FEATURES]>, Vec<f32>) {
        assert_eq!(windows.len(), baseline.len());
        let mut out_feats = Vec::with_capacity(windows.len());
        let mut out_z = Vec::with_capacity(windows.len());
        // Process in fixed-shape blocks of W windows (pad the tail).
        for chunk_start in (0..windows.len()).step_by(self.windows) {
            let end = (chunk_start + self.windows).min(windows.len());
            let mut win_flat = Vec::with_capacity(self.windows * self.samples);
            let mut base_flat = Vec::with_capacity(self.windows * 2);
            for i in chunk_start..chunk_start + self.windows {
                if i < end {
                    let row = &windows[i];
                    assert_eq!(row.len(), self.samples, "pack windows to {} samples", self.samples);
                    win_flat.extend_from_slice(row);
                    base_flat.push(baseline[i].0);
                    base_flat.push(baseline[i].1);
                } else {
                    win_flat.extend(std::iter::repeat(0.0).take(self.samples));
                    base_flat.extend_from_slice(&[0.0, 1.0]);
                }
            }
            let (feats, z) = self.score_block(&win_flat, &base_flat).expect("PJRT scorer failed");
            for i in 0..(end - chunk_start) {
                let mut row = [0f32; N_FEATURES];
                row.copy_from_slice(&feats[i * N_FEATURES..(i + 1) * N_FEATURES]);
                out_feats.push(row);
                out_z.push(z[i]);
            }
        }
        (out_feats, out_z)
    }

    fn name(&self) -> &'static str {
        "compiled-pallas"
    }
}
