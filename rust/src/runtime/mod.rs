//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, HLO text)
//! produced by `python/compile/aot.py`, compiles them on the CPU PJRT
//! client, and exposes:
//!
//! * [`TransformerSession`] — real prefill/decode with a persistent KV cache
//!   (implements `engine::ComputeBackend`, so the serving engine generates
//!   *actual* tokens through the compiled model), and
//! * [`CompiledScorer`] — the Pallas telemetry-scoring kernel as a
//!   `dpu::ScorerBackend`.
//!
//! Python never runs at serving time; these executables are self-contained.
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos — see /opt/xla-example/README.md).

pub mod artifacts;
pub mod model;
pub mod scorerrt;

pub use artifacts::{ArtifactSet, Manifest};
pub use model::TransformerSession;
pub use scorerrt::CompiledScorer;

use anyhow::Result;

/// Create the PJRT CPU client (one per process is plenty).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}
