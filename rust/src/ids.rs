//! Shared identifier newtypes. Kept crate-root so cluster, telemetry, engine
//! and dpu modules can all speak the same vocabulary without cycles.

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
        pub struct $name(pub u32);

        impl $name {
            pub fn idx(self) -> usize {
                self.0 as usize
            }
        }

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}{}", stringify!($name), self.0)
            }
        }
    };
}

id_type!(
    /// A host node (CPU + GPUs + NIC + DPU).
    NodeId
);
id_type!(
    /// A GPU within the cluster (globally indexed).
    GpuId
);
id_type!(
    /// A network flow (one client session / RPC stream).
    FlowId
);
id_type!(
    /// A fabric or PCIe link.
    LinkId
);
id_type!(
    /// An RDMA queue pair.
    QpId
);
id_type!(
    /// One collective operation instance (allreduce / handoff / kv transfer).
    CollId
);
id_type!(
    /// An inference request.
    ReqId
);
id_type!(
    /// A pipeline-parallel stage.
    StageId
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_ordered_and_displayable() {
        assert!(NodeId(1) < NodeId(2));
        assert_eq!(GpuId(3).idx(), 3);
        assert_eq!(format!("{}", ReqId(7)), "ReqId7");
    }
}
