//! Fault injection, dispatched through the condition catalog: each
//! condition's injector (the knobs that create exactly the paper's "likely
//! root cause" for that row) lives in its [`crate::conditions`] spec, and
//! this module is the stable facade the scenario loop and benches call.
//! The behavioral tests stay here: they pin down what injection and healing
//! DO, regardless of where the recipes live.

pub use crate::conditions::{heal_all, inject, site, InjectCtx, InjectSite};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec};
    use crate::dpu::detectors::{Condition, ALL_CONDITIONS};
    use crate::engine::{build_replicas, Engine, EngineConfig};
    use crate::ids::NodeId;
    use crate::sim::dist::{Arrival, LengthDist};
    use crate::workload::generator::WorkloadSpec;

    fn setup() -> (Cluster, Engine, WorkloadSpec) {
        let cfg = EngineConfig::default();
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, cfg.nodes_per_stage);
        (Cluster::new(spec, 1), Engine::new(cfg, plans), WorkloadSpec::default())
    }

    #[test]
    fn every_condition_injects_and_heals() {
        for c in ALL_CONDITIONS {
            let (mut cluster, mut engine, mut wl) = setup();
            let desc = inject(c, NodeId(0), &mut cluster, &mut engine, &mut wl);
            assert!(!desc.is_empty(), "{c:?}");
            // Something must actually have changed somewhere.
            let changed = !cluster.all_healthy()
                || matches!(site(c), InjectSite::Workload | InjectSite::Engine);
            assert!(changed, "{c:?} changed nothing");
            heal_all(&mut cluster, &mut engine, &mut wl);
            assert!(cluster.all_healthy(), "{c:?} not healed");
            for r in &engine.replicas {
                r.plan.check().unwrap();
            }
        }
    }

    #[test]
    fn sites_partition_sensibly() {
        assert_eq!(site(Condition::Ns1BurstBacklog), InjectSite::Workload);
        assert_eq!(site(Condition::Pc5PcieSaturation), InjectSite::Node);
        assert_eq!(site(Condition::Ew6Retransmissions), InjectSite::Fabric);
        assert_eq!(site(Condition::Ew2PpBubble), InjectSite::Engine);
        assert_eq!(site(Condition::Dp1RouterFlowSkew), InjectSite::Workload);
        assert_eq!(site(Condition::Dp2HotReplicaKv), InjectSite::Engine);
        assert_eq!(site(Condition::Dp3StragglerReplica), InjectSite::Node);
    }

    #[test]
    fn dp_family_injects_on_the_victim_replica_and_heals() {
        use crate::dpu::detectors::DP_CONDITIONS;
        // Single-node stages => the default 4-node cluster yields 2 replicas.
        for c in DP_CONDITIONS {
            let mut ecfg = EngineConfig::default();
            ecfg.nodes_per_stage = 1;
            let spec = ClusterSpec::default();
            let plans = build_replicas(&spec, 1);
            let mut engine = Engine::new(ecfg, plans);
            let mut cluster = Cluster::new(spec, 1);
            let mut wl = WorkloadSpec::default();
            assert_eq!(engine.n_replicas(), 2);
            let target = engine.replicas[1].plan.entry_nodes()[0];
            let desc = inject(c, target, &mut cluster, &mut engine, &mut wl);
            assert!(!desc.is_empty(), "{c:?}");
            match c {
                Condition::Dp2HotReplicaKv => {
                    assert!(engine.replicas[1].kv.is_restricted());
                    assert!(!engine.replicas[0].kv.is_restricted());
                }
                Condition::Dp3StragglerReplica => {
                    // Every GPU of replica 1's nodes slowed; replica 0 intact.
                    for n in engine.replicas[1].plan.all_nodes() {
                        assert!(cluster.nodes[n.idx()]
                            .knobs
                            .gpu_speed_factor
                            .iter()
                            .all(|&f| f < 1.0));
                    }
                    for n in engine.replicas[0].plan.all_nodes() {
                        assert!(cluster.nodes[n.idx()].knobs.is_healthy());
                    }
                }
                _ => {
                    assert!(wl.session_skew > 0.0, "DP1 must skew sessions");
                }
            }
            heal_all(&mut cluster, &mut engine, &mut wl);
            assert!(cluster.all_healthy(), "{c:?} not healed");
            assert!(engine.replicas.iter().all(|r| !r.kv.is_restricted()));
        }
    }

    #[test]
    fn pd_family_injects_on_the_disaggregated_fleet_and_heals() {
        use crate::cluster::{ReplicaRole, ReplicaShape};
        use crate::dpu::detectors::PD_CONDITIONS;
        for c in PD_CONDITIONS {
            let mut spec = ClusterSpec::default();
            spec.n_nodes = 6;
            let shapes = vec![
                ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
                ReplicaShape::new(ReplicaRole::Decode, 4, 2),
                ReplicaShape::new(ReplicaRole::Decode, 4, 2),
            ];
            let mut ecfg = EngineConfig::default();
            ecfg.shapes = Some(shapes.clone());
            let plans = crate::engine::build_shaped_replicas(&spec, &shapes);
            let mut engine = Engine::new(ecfg, plans);
            let mut cluster = Cluster::new(spec, 1);
            let mut wl = WorkloadSpec::default();
            // Victimize the second decode replica (index 2), like the
            // disagg sweep does.
            let target = engine.replicas[2].plan.entry_nodes()[0];
            let desc = inject(c, target, &mut cluster, &mut engine, &mut wl);
            assert!(!desc.is_empty(), "{c:?}");
            match c {
                Condition::Pd1PrefillSaturation => {
                    assert!(matches!(wl.prompt_len, LengthDist::Uniform { lo: 48, .. }));
                }
                Condition::Pd2KvHandoffStall => {
                    assert!(cluster.fabric_knobs.handoff_budget_factor < 1.0);
                    assert_eq!(cluster.fabric_knobs.kv_link_budget_factor, 1.0);
                }
                _ => {
                    assert_eq!(engine.decode_router.pin(), Some(2));
                }
            }
            heal_all(&mut cluster, &mut engine, &mut wl);
            assert!(cluster.all_healthy(), "{c:?} not healed");
            assert_eq!(engine.decode_router.pin(), None);
        }
    }

    #[test]
    fn pd3_pin_falls_back_to_a_decode_member_for_non_decode_targets() {
        use crate::cluster::{ReplicaRole, ReplicaShape};
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 6;
        let shapes = vec![
            ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
        ];
        let mut ecfg = EngineConfig::default();
        ecfg.shapes = Some(shapes.clone());
        let plans = crate::engine::build_shaped_replicas(&spec, &shapes);
        let mut engine = Engine::new(ecfg, plans);
        let mut cluster = Cluster::new(spec, 1);
        let mut wl = WorkloadSpec::default();
        // Target the prefill replica's node: the pin must land in the
        // decode pool anyway.
        let target = engine.replicas[0].plan.entry_nodes()[0];
        inject(Condition::Pd3DecodeStarvation, target, &mut cluster, &mut engine, &mut wl);
        assert_eq!(engine.decode_router.pin(), Some(1));
    }

    #[test]
    fn plan_skews_remain_normalized() {
        let (mut cluster, mut engine, mut wl) = setup();
        inject(Condition::Ew3CrossNodeSkew, NodeId(0), &mut cluster, &mut engine, &mut wl);
        for r in &engine.replicas {
            r.plan.check().unwrap();
        }
        inject(Condition::Pc4IntraNodeSkew, NodeId(0), &mut cluster, &mut engine, &mut wl);
        for r in &engine.replicas {
            r.plan.check().unwrap();
        }
    }

    #[test]
    fn injected_descriptions_match_the_catalog_recipes() {
        // The facade and the catalog agree: dispatching through either path
        // produces the same world mutation and description.
        let (mut cluster, mut engine, mut wl) = setup();
        let desc =
            inject(Condition::Ew6Retransmissions, NodeId(0), &mut cluster, &mut engine, &mut wl);
        assert!(desc.contains("10% fabric loss"));
        assert_eq!(cluster.fabric_knobs.loss_prob, 0.10);
        let mut arrival_changed = false;
        if let Arrival::OnOff { .. } = wl.arrival {
            arrival_changed = true;
        }
        assert!(!arrival_changed, "EW6 must not touch the workload");
    }
}
