//! Fault injection: one injector per runbook condition. Each injector turns
//! the knobs that create exactly the paper's "likely root cause" for that
//! row, so the detection benches validate signal → condition → directive
//! end to end.

use crate::cluster::Cluster;
use crate::dpu::detectors::Condition;
use crate::engine::Engine;
use crate::ids::NodeId;
use crate::sim::dist::{Arrival, LengthDist};
use crate::workload::generator::WorkloadSpec;

/// Where a condition's knobs live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectSite {
    /// Per-node hardware knobs (which node matters).
    Node,
    /// Fabric-wide knobs.
    Fabric,
    /// Workload generator shape.
    Workload,
    /// Engine policy / parallel plan.
    Engine,
}

/// Which subsystem an injection touches (used by scenarios to decide whether
/// the workload generator must be rebuilt).
pub fn site(c: Condition) -> InjectSite {
    use Condition::*;
    match c {
        Ns1BurstBacklog | Ns2IngressStarvation | Ns3FlowSkew => InjectSite::Workload,
        Ns8EarlyCompletion | Pc10DecodeEarlyStop => InjectSite::Workload,
        Dp1RouterFlowSkew | Pd1PrefillSaturation => InjectSite::Workload,
        Ew2PpBubble | Ew3CrossNodeSkew | Dp2HotReplicaKv | Pd3DecodeStarvation => {
            InjectSite::Engine
        }
        Ew4Congestion | Ew5HolBlocking | Ew6Retransmissions | Ew7CreditStarvation
        | Ew8KvBottleneck | Pd2KvHandoffStall => InjectSite::Fabric,
        _ => InjectSite::Node,
    }
}

/// Apply the injection for `c`. `target` selects the victim node for
/// node-scoped conditions (for egress-side conditions pass an exit node;
/// for ingress/PCIe conditions an entry node). Returns a description of
/// what was injected (for EXPERIMENTS.md / report evidence).
pub fn inject(
    c: Condition,
    target: NodeId,
    cluster: &mut Cluster,
    engine: &mut Engine,
    wl: &mut WorkloadSpec,
) -> String {
    use Condition::*;
    let knobs = &mut cluster.nodes[target.idx()].knobs;
    match c {
        // ---- workload-shaped (Table 3a root causes) ----
        Ns1BurstBacklog => {
            wl.arrival = Arrival::OnOff {
                on_rate: 3000.0,
                off_rate: 5.0,
                mean_on_s: 0.02,
                mean_off_s: 0.08,
            };
            "ON-OFF client bursts (3000 req/s in 20ms spikes)".into()
        }
        Ns2IngressStarvation => {
            // Upstream service jitter: traffic pauses entirely for long
            // stretches, then resumes at the normal rate (thin, gappy feed).
            wl.arrival = Arrival::OnOff {
                on_rate: 400.0,
                off_rate: 0.0,
                mean_on_s: 0.025,
                mean_off_s: 0.12,
            };
            wl.thin_session_frac = 0.4;
            wl.thin_extra_gap_s = 0.05;
            "upstream jitter: ~120ms silences between normal-rate bursts".into()
        }
        Ns3FlowSkew => {
            wl.session_skew = 1.6;
            "Zipf(1.6) session selection: few flows dominate ingress".into()
        }
        Ns8EarlyCompletion => {
            wl.output_len = LengthDist::Bimodal { short: 2, long: 48, p_short: 0.5 };
            for r in &mut engine.replicas {
                r.batcher.policy_mut().inflight_remap = false;
            }
            "bimodal output lengths (2 vs 48 tokens), freed slots not remapped".into()
        }
        Pc10DecodeEarlyStop => {
            wl.output_len = LengthDist::Bimodal { short: 2, long: 48, p_short: 0.6 };
            for r in &mut engine.replicas {
                r.batcher.policy_mut().inflight_remap = false;
            }
            "sequence-length variance with no decode rebalancing".into()
        }
        // ---- node hardware knobs (Tables 3a/3b root causes) ----
        Ns4IngressRetx => {
            knobs.nic_rx_loss = 0.15;
            format!("15% ingress loss on {target} (MTU mismatch/link errors)")
        }
        Ns5EgressBacklog => {
            knobs.cpu_contention = 3.5;
            knobs.nic_tx_buffer_factor = 0.35;
            format!("CPU copy bottleneck + small TX buffers on {target}")
        }
        Ns6EgressJitter => {
            knobs.egress_jitter = 3.0;
            format!("egress scheduler variance on {target}")
        }
        Ns7EgressRetx => {
            knobs.nic_tx_loss = 0.15;
            format!("15% egress loss on {target} (offload misconfig)")
        }
        Ns9BandwidthSaturation => {
            knobs.nic_background_frac = 0.85;
            format!("background tenant burns 85% of {target}'s NIC")
        }
        Pc1H2dStarvation => {
            knobs.h2d_bw_factor = 0.12;
            knobs.unpinned_buffers = true;
            format!("H2D capped to 12% + pageable buffers on {target}")
        }
        Pc2D2hBottleneck => {
            knobs.d2h_bw_factor = 0.12;
            knobs.pcie_extra_lat_ns = 25_000;
            format!("D2H capped to 12% + IOMMU contention on {target}")
        }
        Pc3LaunchLatency => {
            knobs.doorbell_delay_ns = 150_000;
            knobs.kernel_fission = 12;
            format!("runtime launch overhead + tiny-kernel storm on {target}")
        }
        Pc4IntraNodeSkew => {
            // Memory pressure on one GPU: the scheduler underfeeds it.
            let stage_idx = engine
                .replicas
                .iter()
                .position(|r| r.plan.stages.iter().any(|s| s.nodes.contains(&target)));
            if let Some(ri) = stage_idx {
                let plan = &mut engine.replicas[ri].plan;
                let si = plan.stages.iter().position(|s| s.nodes.contains(&target)).unwrap();
                let gi = plan.stages[si]
                    .gpus
                    .iter()
                    .position(|&g| cluster.spec.node_of_gpu(g) == target)
                    .unwrap();
                plan.skew_shards(si, gi, 0.1);
            }
            cluster.nodes[target.idx()].knobs.gpu_speed_factor[0] = 0.6;
            format!("one GPU on {target} underfed (memory pressure) and slowed")
        }
        Pc5PcieSaturation => {
            knobs.pcie_background_load = 0.8;
            format!("competing DMA tenant burns 80% of {target}'s PCIe")
        }
        Pc6P2pThrottling => {
            knobs.p2p_over_pcie = true;
            knobs.pcie_background_load = 0.3;
            format!("P2P forced over shared PCIe switch on {target}")
        }
        Pc7PinnedShortage => {
            knobs.pinned_pool_frag = true;
            format!("pinned pool fragmented on {target}: DMAs split small")
        }
        Pc8HostCpuBottleneck => {
            knobs.cpu_contention = 4.0;
            knobs.doorbell_delay_ns = 60_000;
            format!("host CPU contention on {target}: doorbells delayed")
        }
        Pc9RegistrationChurn => {
            knobs.mem_reg_churn = true;
            format!("short-lived buffers: map/unmap around every DMA on {target}")
        }
        Ew1TpStraggler => {
            knobs.gpu_speed_factor[0] = 0.2;
            format!("GPU0 on {target} runs at 20% speed (straggling shard)")
        }
        Ew9EarlyStopSkew => {
            knobs.collective_silence = 0.5;
            format!("{target} goes silent in 50% of collectives (unmasked early exit)")
        }
        // ---- engine / plan (Table 3c root causes) ----
        Ew2PpBubble => {
            for r in &mut engine.replicas {
                r.plan.overload_stage(0, 3.0);
            }
            "stage 0 mispartitioned (3x recompute): downstream stages idle".into()
        }
        Ew3CrossNodeSkew => {
            for r in &mut engine.replicas {
                let n_g = r.plan.stages[0].shard_frac.len();
                for g in 0..n_g / 2 {
                    r.plan.skew_shards(0, g, 4.0);
                }
            }
            "activation partitioning misaligned: one node owns most shards".into()
        }
        // ---- fabric knobs ----
        Ew4Congestion => {
            cluster.fabric_knobs.hot_uplink_load = 5.0;
            cluster.fabric_knobs.hot_node = None;
            "fat-tree uplinks oversubscribed 5x (hot ToR)".into()
        }
        Ew5HolBlocking => {
            cluster.fabric_knobs.hol_blocking = true;
            "shared-queue exhaustion: flows serialize through one queue".into()
        }
        Ew6Retransmissions => {
            cluster.fabric_knobs.loss_prob = 0.10;
            "10% fabric loss (misconfigured PFC)".into()
        }
        Ew7CreditStarvation => {
            cluster.fabric_knobs.credit_window = 2;
            "RDMA QP window shrunk to 2 (credit depletion)".into()
        }
        Ew8KvBottleneck => {
            cluster.fabric_knobs.kv_link_budget_factor = 0.12;
            wl.prompt_len = LengthDist::Uniform { lo: 48, hi: 64 };
            "sharded KV exceeds link budget (12%) with long prompts".into()
        }
        // ---- data-parallel fleet family (DP1-DP3) ----
        Dp1RouterFlowSkew => {
            wl.n_sessions = 12;
            wl.session_skew = 2.5;
            if let Arrival::Poisson { rate } = &wl.arrival {
                let surged = rate * 2.5;
                wl.arrival = Arrival::Poisson { rate: surged };
            }
            engine.router.set_policy(crate::engine::RoutePolicy::FlowHash);
            "flash crowd: Zipf(2.5) over 12 sessions at 2.5x rate under affinity hashing".into()
        }
        Dp2HotReplicaKv => {
            let ri = engine.replica_of_node(target).unwrap_or(0);
            engine.replicas[ri].kv.start_leak();
            format!("replica {ri} KV allocator leaks: freed pages never return, admissions thrash")
        }
        Dp3StragglerReplica => {
            let ri = engine.replica_of_node(target).unwrap_or(0);
            for n in engine.replicas[ri].plan.all_nodes() {
                for f in &mut cluster.nodes[n.idx()].knobs.gpu_speed_factor {
                    *f = 0.05;
                }
            }
            format!("replica {ri} degraded: every GPU at 5% speed (straggler replica)")
        }
        // ---- phase-disaggregation family (PD1-PD3) ----
        Pd1PrefillSaturation => {
            // Prompt flood: long prompts at a surged rate overrun the
            // prefill pool while decode demand (tokens out) barely moves.
            wl.prompt_len = LengthDist::Uniform { lo: 48, hi: 64 };
            if let Arrival::Poisson { rate } = &wl.arrival {
                let surged = rate * 2.5;
                wl.arrival = Arrival::Poisson { rate: surged };
            }
            "prompt flood: 48-64-token prompts at 2.5x rate overrun the prefill pool".into()
        }
        Pd2KvHandoffStall => {
            cluster.fabric_knobs.handoff_budget_factor = 0.2;
            "prefill→decode KV-handoff link budget collapsed to 20%".into()
        }
        Pd3DecodeStarvation => {
            // Wedged handoff routing: every phase transition lands on one
            // decode replica; its pool peers starve.
            let hot = engine
                .replica_of_node(target)
                .filter(|&ri| engine.replicas[ri].plan.shape.role.serves_decode())
                .unwrap_or_else(|| engine.decode_router.members()[0]);
            engine.decode_router.set_pin(Some(hot));
            format!("handoff routing wedged: every KV handoff lands on decode replica {hot}")
        }
    }
}

/// Revert everything an injection touched (used between bench scenarios).
pub fn heal_all(cluster: &mut Cluster, engine: &mut Engine, wl: &mut WorkloadSpec) {
    cluster.heal();
    for r in &mut engine.replicas {
        r.plan.rebalance();
        r.kv.restore_capacity();
        let pol = r.batcher.policy_mut();
        pol.inflight_remap = true;
        pol.continuous = true;
    }
    engine.reset_roles();
    engine.router.clear_overrides();
    engine.router.clear_drained();
    engine.decode_router.set_pin(None);
    engine.decode_router.clear_overrides();
    engine.decode_router.clear_drained();
    *wl = WorkloadSpec::default();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::dpu::detectors::ALL_CONDITIONS;
    use crate::engine::{build_replicas, EngineConfig};

    fn setup() -> (Cluster, Engine, WorkloadSpec) {
        let cfg = EngineConfig::default();
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, cfg.nodes_per_stage);
        (Cluster::new(spec, 1), Engine::new(cfg, plans), WorkloadSpec::default())
    }

    #[test]
    fn every_condition_injects_and_heals() {
        for c in ALL_CONDITIONS {
            let (mut cluster, mut engine, mut wl) = setup();
            let desc = inject(c, NodeId(0), &mut cluster, &mut engine, &mut wl);
            assert!(!desc.is_empty(), "{c:?}");
            // Something must actually have changed somewhere.
            let changed = !cluster.all_healthy()
                || matches!(site(c), InjectSite::Workload | InjectSite::Engine);
            assert!(changed, "{c:?} changed nothing");
            heal_all(&mut cluster, &mut engine, &mut wl);
            assert!(cluster.all_healthy(), "{c:?} not healed");
            for r in &engine.replicas {
                r.plan.check().unwrap();
            }
        }
    }

    #[test]
    fn sites_partition_sensibly() {
        assert_eq!(site(Condition::Ns1BurstBacklog), InjectSite::Workload);
        assert_eq!(site(Condition::Pc5PcieSaturation), InjectSite::Node);
        assert_eq!(site(Condition::Ew6Retransmissions), InjectSite::Fabric);
        assert_eq!(site(Condition::Ew2PpBubble), InjectSite::Engine);
        assert_eq!(site(Condition::Dp1RouterFlowSkew), InjectSite::Workload);
        assert_eq!(site(Condition::Dp2HotReplicaKv), InjectSite::Engine);
        assert_eq!(site(Condition::Dp3StragglerReplica), InjectSite::Node);
    }

    #[test]
    fn dp_family_injects_on_the_victim_replica_and_heals() {
        use crate::dpu::detectors::DP_CONDITIONS;
        // Single-node stages => the default 4-node cluster yields 2 replicas.
        for c in DP_CONDITIONS {
            let mut ecfg = EngineConfig::default();
            ecfg.nodes_per_stage = 1;
            let spec = ClusterSpec::default();
            let plans = build_replicas(&spec, 1);
            let mut engine = Engine::new(ecfg, plans);
            let mut cluster = Cluster::new(spec, 1);
            let mut wl = WorkloadSpec::default();
            assert_eq!(engine.n_replicas(), 2);
            let target = engine.replicas[1].plan.entry_nodes()[0];
            let desc = inject(c, target, &mut cluster, &mut engine, &mut wl);
            assert!(!desc.is_empty(), "{c:?}");
            match c {
                Condition::Dp2HotReplicaKv => {
                    assert!(engine.replicas[1].kv.is_restricted());
                    assert!(!engine.replicas[0].kv.is_restricted());
                }
                Condition::Dp3StragglerReplica => {
                    // Every GPU of replica 1's nodes slowed; replica 0 intact.
                    for n in engine.replicas[1].plan.all_nodes() {
                        assert!(cluster.nodes[n.idx()]
                            .knobs
                            .gpu_speed_factor
                            .iter()
                            .all(|&f| f < 1.0));
                    }
                    for n in engine.replicas[0].plan.all_nodes() {
                        assert!(cluster.nodes[n.idx()].knobs.is_healthy());
                    }
                }
                _ => {
                    assert!(wl.session_skew > 0.0, "DP1 must skew sessions");
                }
            }
            heal_all(&mut cluster, &mut engine, &mut wl);
            assert!(cluster.all_healthy(), "{c:?} not healed");
            assert!(engine.replicas.iter().all(|r| !r.kv.is_restricted()));
        }
    }

    #[test]
    fn pd_family_injects_on_the_disaggregated_fleet_and_heals() {
        use crate::cluster::{ReplicaRole, ReplicaShape};
        use crate::dpu::detectors::PD_CONDITIONS;
        for c in PD_CONDITIONS {
            let mut spec = ClusterSpec::default();
            spec.n_nodes = 6;
            let shapes = vec![
                ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
                ReplicaShape::new(ReplicaRole::Decode, 4, 2),
                ReplicaShape::new(ReplicaRole::Decode, 4, 2),
            ];
            let mut ecfg = EngineConfig::default();
            ecfg.shapes = Some(shapes.clone());
            let plans = crate::engine::build_shaped_replicas(&spec, &shapes);
            let mut engine = Engine::new(ecfg, plans);
            let mut cluster = Cluster::new(spec, 1);
            let mut wl = WorkloadSpec::default();
            // Victimize the second decode replica (index 2), like the
            // disagg sweep does.
            let target = engine.replicas[2].plan.entry_nodes()[0];
            let desc = inject(c, target, &mut cluster, &mut engine, &mut wl);
            assert!(!desc.is_empty(), "{c:?}");
            match c {
                Condition::Pd1PrefillSaturation => {
                    assert!(matches!(wl.prompt_len, LengthDist::Uniform { lo: 48, .. }));
                }
                Condition::Pd2KvHandoffStall => {
                    assert!(cluster.fabric_knobs.handoff_budget_factor < 1.0);
                    assert_eq!(cluster.fabric_knobs.kv_link_budget_factor, 1.0);
                }
                _ => {
                    assert_eq!(engine.decode_router.pin(), Some(2));
                }
            }
            heal_all(&mut cluster, &mut engine, &mut wl);
            assert!(cluster.all_healthy(), "{c:?} not healed");
            assert_eq!(engine.decode_router.pin(), None);
        }
    }

    #[test]
    fn pd3_pin_falls_back_to_a_decode_member_for_non_decode_targets() {
        use crate::cluster::{ReplicaRole, ReplicaShape};
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 6;
        let shapes = vec![
            ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
        ];
        let mut ecfg = EngineConfig::default();
        ecfg.shapes = Some(shapes.clone());
        let plans = crate::engine::build_shaped_replicas(&spec, &shapes);
        let mut engine = Engine::new(ecfg, plans);
        let mut cluster = Cluster::new(spec, 1);
        let mut wl = WorkloadSpec::default();
        // Target the prefill replica's node: the pin must land in the
        // decode pool anyway.
        let target = engine.replicas[0].plan.entry_nodes()[0];
        inject(Condition::Pd3DecodeStarvation, target, &mut cluster, &mut engine, &mut wl);
        assert_eq!(engine.decode_router.pin(), Some(1));
    }

    #[test]
    fn plan_skews_remain_normalized() {
        let (mut cluster, mut engine, mut wl) = setup();
        inject(Condition::Ew3CrossNodeSkew, NodeId(0), &mut cluster, &mut engine, &mut wl);
        for r in &engine.replicas {
            r.plan.check().unwrap();
        }
        inject(Condition::Pc4IntraNodeSkew, NodeId(0), &mut cluster, &mut engine, &mut wl);
        for r in &engine.replicas {
            r.plan.check().unwrap();
        }
    }
}
