//! Serving metrics (TTFT/TPOT/throughput), detection-quality metrics
//! (confusion matrix, detection latency), and the paper-style report
//! renderers used by every bench.

use std::collections::{BTreeMap, HashMap};

use crate::dpu::detectors::{Condition, Detection, ALL_CONDITIONS};
use crate::ids::ReqId;
use crate::sim::{SimDur, SimTime};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::{fmt_ns, Table};
use crate::workload::request::InferenceRequest;

/// One replica's serving lane — the data-parallel skew view of a run.
#[derive(Debug, Default, Clone)]
pub struct ReplicaLane {
    pub completed: u64,
    pub rejected: u64,
    pub tokens_out: u64,
}

/// Aggregated serving-quality metrics for one run.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub ttft_ns: Summary,
    pub tpot_ns: Summary,
    pub e2e_ns: Summary,
    pub completed: u64,
    pub rejected: u64,
    pub tokens_out: u64,
    pub span: SimDur,
    /// Per-replica lanes (empty for single-lane collectors).
    pub per_replica: Vec<ReplicaLane>,
}

impl ServeMetrics {
    /// Collect from finished requests; `span` is the measured sim interval.
    pub fn collect<'a>(reqs: impl Iterator<Item = &'a InferenceRequest>, span: SimDur) -> Self {
        Self::collect_fleet(reqs, &HashMap::new(), 0, span)
    }

    /// Collect with per-replica lanes: `placement` maps each request to the
    /// replica that served it (the engine's routing record).
    pub fn collect_fleet<'a>(
        reqs: impl Iterator<Item = &'a InferenceRequest>,
        placement: &HashMap<ReqId, usize>,
        n_replicas: usize,
        span: SimDur,
    ) -> Self {
        let mut m = ServeMetrics {
            span,
            per_replica: vec![ReplicaLane::default(); n_replicas],
            ..Default::default()
        };
        for r in reqs {
            let lane = placement.get(&r.id).copied().filter(|&i| i < n_replicas);
            match r.state {
                crate::workload::request::ReqState::Done => {
                    m.completed += 1;
                    let toks = r.tokens_generated() as u64;
                    m.tokens_out += toks;
                    if let Some(i) = lane {
                        m.per_replica[i].completed += 1;
                        m.per_replica[i].tokens_out += toks;
                    }
                    if let Some(ttft) = r.ttft() {
                        m.ttft_ns.push(ttft.ns() as f64);
                    }
                    if let Some(tpot) = r.tpot_ns() {
                        m.tpot_ns.push(tpot);
                    }
                    if let Some(done) = r.done_at {
                        m.e2e_ns.push((done - r.arrival).ns() as f64);
                    }
                }
                crate::workload::request::ReqState::Rejected => {
                    m.rejected += 1;
                    if let Some(i) = lane {
                        m.per_replica[i].rejected += 1;
                    }
                }
                _ => {}
            }
        }
        m
    }

    /// Max-over-mean token share across replica lanes: 1.0 is perfectly
    /// balanced, `n_replicas` is total concentration. Degenerate cases
    /// (no lanes, no tokens) report 1.0.
    pub fn replica_token_skew(&self) -> f64 {
        lane_skew(self.per_replica.iter().map(|l| l.tokens_out))
    }

    /// Max-over-mean completed-request share across replica lanes.
    pub fn replica_completed_skew(&self) -> f64 {
        lane_skew(self.per_replica.iter().map(|l| l.completed))
    }

    pub fn req_per_s(&self) -> f64 {
        self.completed as f64 / self.span.as_secs_f64().max(1e-9)
    }

    pub fn tok_per_s(&self) -> f64 {
        self.tokens_out as f64 / self.span.as_secs_f64().max(1e-9)
    }

    /// One-line summary for logs.
    pub fn brief(&self) -> String {
        format!(
            "{} done ({} rejected), {:.0} tok/s, TTFT p50 {} p99 {}, TPOT p50 {}",
            self.completed,
            self.rejected,
            self.tok_per_s(),
            fmt_ns(self.ttft_ns.p50()),
            fmt_ns(self.ttft_ns.p99()),
            fmt_ns(self.tpot_ns.p50()),
        )
    }

    /// Table row cells (shared layout across benches).
    pub fn row_cells(&self, label: &str) -> Vec<String> {
        vec![
            label.to_string(),
            format!("{}", self.completed),
            format!("{:.1}", self.req_per_s()),
            format!("{:.0}", self.tok_per_s()),
            fmt_ns(self.ttft_ns.p50()),
            fmt_ns(self.ttft_ns.p95()),
            fmt_ns(self.ttft_ns.p99()),
            fmt_ns(self.tpot_ns.p50()),
            fmt_ns(self.tpot_ns.p99()),
        ]
    }

    pub fn table_header() -> [&'static str; 9] {
        ["scenario", "done", "req/s", "tok/s", "ttft p50", "ttft p95", "ttft p99", "tpot p50", "tpot p99"]
    }

    /// Machine-readable form (bench trajectory files, fleet reports).
    pub fn to_json(&self, label: &str) -> Json {
        let mut lanes = Json::arr();
        for (i, l) in self.per_replica.iter().enumerate() {
            lanes.push(
                Json::obj()
                    .set("replica", i)
                    .set("completed", l.completed)
                    .set("rejected", l.rejected)
                    .set("tokens_out", l.tokens_out),
            );
        }
        Json::obj()
            .set("label", label)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("tokens_out", self.tokens_out)
            .set("req_per_s", self.req_per_s())
            .set("tok_per_s", self.tok_per_s())
            .set("ttft_p50_ns", self.ttft_ns.p50())
            .set("ttft_p95_ns", self.ttft_ns.p95())
            .set("ttft_p99_ns", self.ttft_ns.p99())
            .set("tpot_p50_ns", self.tpot_ns.p50())
            .set("tpot_p99_ns", self.tpot_ns.p99())
            .set("replica_token_skew", self.replica_token_skew())
            .set("per_replica", lanes)
    }
}

/// One tenant class's SLO lane: latency summaries plus exact attainment
/// counts. Everything here is either an integer count or a sort-based
/// percentile, so collection order (e.g. hash-map iteration) cannot perturb
/// the reported numbers — the campaign JSON stays byte-deterministic.
#[derive(Debug, Clone, Default)]
pub struct TenantLane {
    pub name: String,
    pub priority: u8,
    pub ttft_slo_ms: f64,
    pub tpot_slo_ms: f64,
    pub completed: u64,
    pub rejected: u64,
    pub tokens_out: u64,
    pub ttft_ns: Summary,
    pub tpot_ns: Summary,
    /// Completed requests whose TTFT met the class SLO / had a TTFT at all.
    pub ttft_ok: u64,
    pub ttft_n: u64,
    /// Completed requests whose mean TPOT met the class SLO / had a TPOT.
    pub tpot_ok: u64,
    pub tpot_n: u64,
}

impl TenantLane {
    /// Fraction of measured requests meeting the TTFT SLO (1.0 when none
    /// were measured — an idle class has not missed its SLO).
    pub fn ttft_attainment(&self) -> f64 {
        if self.ttft_n == 0 {
            1.0
        } else {
            self.ttft_ok as f64 / self.ttft_n as f64
        }
    }

    pub fn tpot_attainment(&self) -> f64 {
        if self.tpot_n == 0 {
            1.0
        } else {
            self.tpot_ok as f64 / self.tpot_n as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("tenant", self.name.as_str())
            .set("priority", self.priority)
            .set("ttft_slo_ms", self.ttft_slo_ms)
            .set("tpot_slo_ms", self.tpot_slo_ms)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("tokens_out", self.tokens_out)
            .set("ttft_p50_ns", self.ttft_ns.p50())
            .set("ttft_p95_ns", self.ttft_ns.p95())
            .set("ttft_p99_ns", self.ttft_ns.p99())
            .set("tpot_p50_ns", self.tpot_ns.p50())
            .set("tpot_p99_ns", self.tpot_ns.p99())
            .set("ttft_attainment", self.ttft_attainment())
            .set("tpot_attainment", self.tpot_attainment())
    }
}

/// Collect per-tenant SLO lanes from finished requests. Classes come from
/// `WorkloadSpec::tenants`; when empty, everything lands in one implicit
/// lane named "all" with unbounded SLOs (attainment 1.0 by construction).
/// Order-insensitive over `reqs` — safe on hash-map iteration.
pub fn collect_tenants<'a>(
    reqs: impl Iterator<Item = &'a InferenceRequest>,
    classes: &[crate::workload::tenant::TenantClass],
) -> Vec<TenantLane> {
    let mut lanes: Vec<TenantLane> = if classes.is_empty() {
        vec![TenantLane {
            name: "all".to_string(),
            ttft_slo_ms: f64::INFINITY,
            tpot_slo_ms: f64::INFINITY,
            ..Default::default()
        }]
    } else {
        classes
            .iter()
            .map(|c| TenantLane {
                name: c.name.clone(),
                priority: c.priority,
                ttft_slo_ms: c.ttft_slo_ms,
                tpot_slo_ms: c.tpot_slo_ms,
                ..Default::default()
            })
            .collect()
    };
    const MS: f64 = 1_000_000.0;
    for r in reqs {
        let lane = &mut lanes[(r.tenant as usize).min(lanes.len() - 1)];
        match r.state {
            crate::workload::request::ReqState::Done => {
                lane.completed += 1;
                lane.tokens_out += r.tokens_generated() as u64;
                if let Some(ttft) = r.ttft() {
                    lane.ttft_ns.push(ttft.ns() as f64);
                    lane.ttft_n += 1;
                    if ttft.ns() as f64 <= lane.ttft_slo_ms * MS {
                        lane.ttft_ok += 1;
                    }
                }
                if let Some(tpot) = r.tpot_ns() {
                    lane.tpot_ns.push(tpot);
                    lane.tpot_n += 1;
                    if tpot <= lane.tpot_slo_ms * MS {
                        lane.tpot_ok += 1;
                    }
                }
            }
            crate::workload::request::ReqState::Rejected => lane.rejected += 1,
            _ => {}
        }
    }
    lanes
}

/// Max-over-mean of a lane counter (shared by the skew columns).
fn lane_skew(lanes: impl Iterator<Item = u64>) -> f64 {
    let v: Vec<u64> = lanes.collect();
    if v.is_empty() {
        return 1.0;
    }
    let total: u64 = v.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / v.len() as f64;
    let max = *v.iter().max().unwrap() as f64;
    max / mean
}

/// Injection × detection confusion accounting for E5.
#[derive(Debug, Default)]
pub struct ConfusionMatrix {
    /// counts[injected][detected]
    counts: BTreeMap<Condition, BTreeMap<Condition, u64>>,
    /// Windows where the injected condition produced no detection at all.
    misses: BTreeMap<Condition, u64>,
    /// Detections fired during healthy (no-injection) runs.
    pub false_alarms: BTreeMap<Condition, u64>,
    pub healthy_windows: u64,
}

impl ConfusionMatrix {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the detections observed while `injected` was active.
    pub fn record(&mut self, injected: Condition, detections: &[Detection], detected_any: bool) {
        let row = self.counts.entry(injected).or_default();
        for d in detections {
            *row.entry(d.condition).or_insert(0) += 1;
        }
        if !detected_any {
            *self.misses.entry(injected).or_insert(0) += 1;
        }
    }

    pub fn record_healthy(&mut self, detections: &[Detection], windows: u64) {
        self.healthy_windows += windows;
        for d in detections {
            *self.false_alarms.entry(d.condition).or_insert(0) += 1;
        }
    }

    /// Count-based variant of [`ConfusionMatrix::record`], for callers that
    /// aggregate detections off-thread and ship back compact per-condition
    /// counts (the parallel matrix runner).
    pub fn record_counts(
        &mut self,
        injected: Condition,
        counts: &[(Condition, u64)],
        detected_any: bool,
    ) {
        let row = self.counts.entry(injected).or_default();
        for (c, n) in counts {
            *row.entry(*c).or_insert(0) += n;
        }
        if !detected_any {
            *self.misses.entry(injected).or_insert(0) += 1;
        }
    }

    /// Count-based variant of [`ConfusionMatrix::record_healthy`].
    pub fn record_healthy_counts(&mut self, counts: &[(Condition, u64)], windows: u64) {
        self.healthy_windows += windows;
        for (c, n) in counts {
            *self.false_alarms.entry(*c).or_insert(0) += n;
        }
    }

    pub fn count(&self, injected: Condition, detected: Condition) -> u64 {
        self.counts.get(&injected).and_then(|r| r.get(&detected)).copied().unwrap_or(0)
    }

    /// True-positive: the injected condition itself fired.
    pub fn hit(&self, injected: Condition) -> bool {
        self.count(injected, injected) > 0
    }

    /// Precision of the diagonal for an injected run: fraction of fired
    /// detections that name the injected condition (or a sibling sharing
    /// the same directive — the runbook treats those as equivalent actions).
    pub fn diagonal_precision(&self, injected: Condition) -> f64 {
        let Some(row) = self.counts.get(&injected) else { return 0.0 };
        let total: u64 = row.values().sum();
        if total == 0 {
            return 0.0;
        }
        let inj_dir = crate::dpu::runbook::entry(injected).directive;
        let good: u64 = row
            .iter()
            .filter(|(c, _)| **c == injected || crate::dpu::runbook::entry(**c).directive == inj_dir)
            .map(|(_, n)| *n)
            .sum();
        good as f64 / total as f64
    }

    /// Macro recall over all conditions recorded.
    pub fn macro_recall(&self) -> f64 {
        let mut hits = 0usize;
        let mut total = 0usize;
        for c in self.counts.keys() {
            total += 1;
            if self.hit(*c) {
                hits += 1;
            }
        }
        if total == 0 { 0.0 } else { hits as f64 / total as f64 }
    }

    /// Render the full 28x28 matrix (sparse rows elided to non-zero cells).
    pub fn render(&self) -> String {
        let mut t = Table::new("Injection x Detection (rows=injected)").header(&[
            "injected", "self-hits", "other detections", "diag precision",
        ]);
        for c in ALL_CONDITIONS {
            if let Some(row) = self.counts.get(&c) {
                let others: Vec<String> = row
                    .iter()
                    .filter(|(k, _)| **k != c)
                    .map(|(k, v)| format!("{}:{}", k.id(), v))
                    .collect();
                t.row(vec![
                    c.id().to_string(),
                    format!("{}", self.count(c, c)),
                    if others.is_empty() { "-".into() } else { others.join(" ") },
                    format!("{:.2}", self.diagonal_precision(c)),
                ]);
            }
        }
        t.render()
    }
}

/// Per-condition detection-quality aggregate across a scenario-matrix run
/// (the machine-readable form of the paper's §§4.1-4.3 evaluation). One
/// scorecard summarizes every replicate of one injected condition, plus how
/// often that condition's detector misfired elsewhere (the false-positive
/// view against the other 27 injections and the healthy controls).
#[derive(Debug, Clone)]
pub struct Scorecard {
    pub condition: Condition,
    /// Injected runs of this condition.
    pub runs: u64,
    /// Runs where the injected condition itself fired after injection.
    pub detected_runs: u64,
    /// Injection -> first correct detection, one sample per detected run.
    pub latency_ns: Summary,
    /// Post-injection firings naming this condition, across its own runs.
    pub self_firings: u64,
    /// Post-injection firings naming OTHER conditions during this
    /// condition's runs (cross-talk emitted).
    pub other_firings: u64,
    /// Directive-aware diagonal precision (from the confusion matrix).
    pub diagonal_precision: f64,
    /// Runs of the OTHER 27 conditions in which this condition fired.
    pub false_positive_runs: u64,
    /// Total runs of the other 27 conditions.
    pub other_condition_runs: u64,
    /// Firings of this condition during healthy (no-injection) runs.
    pub healthy_false_alarms: u64,
    /// Runs whose root-cause attribution matched the expected cause class.
    pub attribution_hits: u64,
    /// Runs where the software-only suite raised any alarm post-injection.
    pub sw_noticed_runs: u64,
    /// Runs where a fired software alarm *identifies* this condition.
    pub sw_identified_runs: u64,
}

impl Scorecard {
    pub fn new(condition: Condition) -> Self {
        Scorecard {
            condition,
            runs: 0,
            detected_runs: 0,
            latency_ns: Summary::new(),
            self_firings: 0,
            other_firings: 0,
            diagonal_precision: 0.0,
            false_positive_runs: 0,
            other_condition_runs: 0,
            healthy_false_alarms: 0,
            attribution_hits: 0,
            sw_noticed_runs: 0,
            sw_identified_runs: 0,
        }
    }

    /// Was the condition identified at least once across replicates?
    pub fn identified(&self) -> bool {
        self.detected_runs > 0
    }

    /// Detection recall over replicates.
    pub fn recall(&self) -> f64 {
        if self.runs == 0 {
            0.0
        } else {
            self.detected_runs as f64 / self.runs as f64
        }
    }

    /// Fraction of other-condition runs in which this detector misfired.
    pub fn false_positive_rate(&self) -> f64 {
        if self.other_condition_runs == 0 {
            0.0
        } else {
            self.false_positive_runs as f64 / self.other_condition_runs as f64
        }
    }

    /// Fraction of runs whose attribution named the expected cause class.
    pub fn attribution_accuracy(&self) -> f64 {
        if self.runs == 0 {
            0.0
        } else {
            self.attribution_hits as f64 / self.runs as f64
        }
    }

    pub fn sw_noticed_rate(&self) -> f64 {
        if self.runs == 0 {
            0.0
        } else {
            self.sw_noticed_runs as f64 / self.runs as f64
        }
    }

    pub fn sw_identified_rate(&self) -> f64 {
        if self.runs == 0 {
            0.0
        } else {
            self.sw_identified_runs as f64 / self.runs as f64
        }
    }

    /// DPU-vs-software coverage verdict for the E5 comparison table.
    pub fn coverage_delta(&self) -> &'static str {
        match (self.identified(), self.sw_identified_runs > 0) {
            (true, false) => "DPU-only",
            (true, true) => "DPU+SW",
            (false, true) => "SW-only",
            (false, false) => "neither",
        }
    }
}

/// Detection latency: injection time -> first correct detection.
pub fn detection_latency(
    detections: &[Detection],
    condition: Condition,
    injected_at: SimTime,
) -> Option<SimDur> {
    detections
        .iter()
        .filter(|d| d.condition == condition && d.at >= injected_at)
        .map(|d| d.at - injected_at)
        .min()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{FlowId, NodeId, ReqId};
    use crate::workload::request::ReqState;

    fn done_req(id: u32, arrival: u64, first: u64, done: u64, toks: usize) -> InferenceRequest {
        let mut r =
            InferenceRequest::new(ReqId(id), FlowId(0), SimTime(arrival), vec![1, 2], toks);
        r.state = ReqState::Done;
        r.first_token_at = Some(SimTime(first));
        r.done_at = Some(SimTime(done));
        r.generated = vec![5; toks];
        r
    }

    #[test]
    fn serve_metrics_aggregate() {
        let reqs = vec![
            done_req(1, 0, 1000, 5000, 5),
            done_req(2, 100, 2000, 6000, 5),
        ];
        let m = ServeMetrics::collect(reqs.iter(), SimDur(10_000));
        assert_eq!(m.completed, 2);
        assert_eq!(m.tokens_out, 10);
        assert!(m.tok_per_s() > 0.0);
        assert_eq!(m.ttft_ns.count(), 2);
        assert!(!m.brief().is_empty());
        assert_eq!(m.row_cells("x").len(), ServeMetrics::table_header().len());
    }

    #[test]
    fn fleet_collect_fills_lanes_and_skew() {
        let reqs = vec![
            done_req(1, 0, 1000, 5000, 6),
            done_req(2, 100, 2000, 6000, 6),
            done_req(3, 200, 2500, 6500, 6),
        ];
        let mut placement = HashMap::new();
        placement.insert(ReqId(1), 0usize);
        placement.insert(ReqId(2), 0usize);
        placement.insert(ReqId(3), 1usize);
        let m = ServeMetrics::collect_fleet(reqs.iter(), &placement, 2, SimDur(10_000));
        assert_eq!(m.completed, 3);
        assert_eq!(m.per_replica.len(), 2);
        assert_eq!(m.per_replica[0].completed, 2);
        assert_eq!(m.per_replica[1].completed, 1);
        assert_eq!(m.per_replica[0].tokens_out, 12);
        // max/mean: 12 / 9 tokens.
        assert!((m.replica_token_skew() - 12.0 / 9.0).abs() < 1e-12);
        assert!((m.replica_completed_skew() - 2.0 / 1.5).abs() < 1e-12);
        let j = m.to_json("fleet").render();
        assert!(j.contains("\"replica_token_skew\""));
        assert!(j.contains("\"per_replica\""));
        // Single-lane collector: skew degenerates to 1.0 and lanes are empty.
        let single = ServeMetrics::collect(reqs.iter(), SimDur(10_000));
        assert!(single.per_replica.is_empty());
        assert_eq!(single.replica_token_skew(), 1.0);
    }

    #[test]
    fn tenant_lanes_score_slo_attainment() {
        use crate::workload::tenant::TenantClass;
        let classes = vec![
            TenantClass::new("interactive", 0, 0.5, 0.003, 0.001), // 3µs TTFT, 1µs TPOT
            TenantClass::new("batch", 1, 0.5, 10.0, 10.0),
        ];
        // interactive: TTFT 1µs (ok) and 5µs (miss); batch: TTFT 2µs (ok).
        let mut a = done_req(1, 0, 1_000, 5_000, 5);
        a.tenant = 0;
        let mut b = done_req(2, 0, 5_000, 9_000, 5);
        b.tenant = 0;
        let mut c = done_req(3, 0, 2_000, 6_000, 5);
        c.tenant = 1;
        let reqs = vec![a, b, c];
        let lanes = collect_tenants(reqs.iter(), &classes);
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].completed, 2);
        assert_eq!((lanes[0].ttft_ok, lanes[0].ttft_n), (1, 2));
        assert!((lanes[0].ttft_attainment() - 0.5).abs() < 1e-12);
        assert_eq!(lanes[1].completed, 1);
        assert!((lanes[1].ttft_attainment() - 1.0).abs() < 1e-12);
        // TPOT: (done-first)/(toks-1) = 1000ns = 1µs; interactive SLO is 1µs.
        assert_eq!(lanes[0].tpot_n, 2);
        assert!(lanes[0].to_json().render().contains("\"ttft_attainment\""));
        // No classes: one implicit lane, attainment 1.0 by construction.
        let implicit = collect_tenants(reqs.iter(), &[]);
        assert_eq!(implicit.len(), 1);
        assert_eq!(implicit[0].completed, 3);
        assert_eq!(implicit[0].ttft_attainment(), 1.0);
    }

    #[test]
    fn confusion_hits_and_precision() {
        let mut cm = ConfusionMatrix::new();
        let d = |c: Condition| Detection {
            condition: c,
            node: NodeId(0),
            at: SimTime(5),
            severity: 4.0,
            evidence: String::new(),
        };
        cm.record(
            Condition::Ew6Retransmissions,
            &[d(Condition::Ew6Retransmissions), d(Condition::Ew6Retransmissions), d(Condition::Ew4Congestion)],
            true,
        );
        assert!(cm.hit(Condition::Ew6Retransmissions));
        let p = cm.diagonal_precision(Condition::Ew6Retransmissions);
        assert!((p - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(cm.macro_recall(), 1.0);
        assert!(cm.render().contains("EW6"));
    }

    #[test]
    fn sibling_directives_count_as_precision() {
        // NS8 and PC10 share EnableInflightRemap: detecting PC10 under NS8
        // injection still drives the right action.
        let mut cm = ConfusionMatrix::new();
        let d = |c: Condition| Detection {
            condition: c,
            node: NodeId(0),
            at: SimTime(5),
            severity: 4.0,
            evidence: String::new(),
        };
        cm.record(Condition::Ns8EarlyCompletion, &[d(Condition::Pc10DecodeEarlyStop)], true);
        assert_eq!(cm.diagonal_precision(Condition::Ns8EarlyCompletion), 1.0);
    }

    #[test]
    fn record_counts_matches_record() {
        let mut a = ConfusionMatrix::new();
        let d = |c: Condition| Detection {
            condition: c,
            node: NodeId(0),
            at: SimTime(5),
            severity: 4.0,
            evidence: String::new(),
        };
        a.record(
            Condition::Ew6Retransmissions,
            &[d(Condition::Ew6Retransmissions), d(Condition::Ew4Congestion)],
            true,
        );
        let mut b = ConfusionMatrix::new();
        b.record_counts(
            Condition::Ew6Retransmissions,
            &[(Condition::Ew6Retransmissions, 1), (Condition::Ew4Congestion, 1)],
            true,
        );
        assert_eq!(
            a.count(Condition::Ew6Retransmissions, Condition::Ew6Retransmissions),
            b.count(Condition::Ew6Retransmissions, Condition::Ew6Retransmissions)
        );
        assert_eq!(
            a.diagonal_precision(Condition::Ew6Retransmissions),
            b.diagonal_precision(Condition::Ew6Retransmissions)
        );
        b.record_healthy_counts(&[(Condition::Ns1BurstBacklog, 2)], 100);
        assert_eq!(b.healthy_windows, 100);
        assert_eq!(b.false_alarms[&Condition::Ns1BurstBacklog], 2);
    }

    #[test]
    fn scorecard_rates() {
        let mut sc = Scorecard::new(Condition::Ew1TpStraggler);
        assert!(!sc.identified());
        assert_eq!(sc.recall(), 0.0);
        assert_eq!(sc.false_positive_rate(), 0.0);
        sc.runs = 4;
        sc.detected_runs = 3;
        sc.false_positive_runs = 9;
        sc.other_condition_runs = 108;
        sc.attribution_hits = 2;
        sc.sw_noticed_runs = 4;
        sc.sw_identified_runs = 0;
        assert!(sc.identified());
        assert!((sc.recall() - 0.75).abs() < 1e-12);
        assert!((sc.false_positive_rate() - 9.0 / 108.0).abs() < 1e-12);
        assert!((sc.attribution_accuracy() - 0.5).abs() < 1e-12);
        assert_eq!(sc.coverage_delta(), "DPU-only");
        sc.detected_runs = 0;
        assert_eq!(sc.coverage_delta(), "neither");
    }

    #[test]
    fn detection_latency_first_match() {
        let d = |c: Condition, at: u64| Detection {
            condition: c,
            node: NodeId(0),
            at: SimTime(at),
            severity: 4.0,
            evidence: String::new(),
        };
        let ds = vec![
            d(Condition::Ew6Retransmissions, 500), // before injection
            d(Condition::Ew6Retransmissions, 2000),
            d(Condition::Ew6Retransmissions, 3000),
        ];
        let lat = detection_latency(&ds, Condition::Ew6Retransmissions, SimTime(1000)).unwrap();
        assert_eq!(lat.ns(), 1000);
        assert!(detection_latency(&ds, Condition::Ew4Congestion, SimTime(0)).is_none());
    }
}
