//! Shared utilities: deterministic RNG, streaming statistics, ring buffers,
//! JSON/table output, `key=value` parsing, and the in-repo property-testing
//! harness. Everything here is dependency-free (offline vendoring constraint)
//! and deterministic.

pub mod alloc;
pub mod cli;
pub mod fastmap;
pub mod json;
pub mod kv;
pub mod par;
pub mod perf;
pub mod prop;
pub mod ring;
pub mod rng;
pub mod stats;
pub mod table;

pub use fastmap::FastMap;
pub use json::Json;
pub use kv::KvFile;
pub use par::parallel_map;
pub use ring::Ring;
pub use rng::{Rng, Zipf};
pub use stats::{Histogram, P2Quantile, Summary, Welford};
pub use table::Table;
