//! Fixed-capacity ring buffer — the telemetry bus's backing store.
//!
//! Overwrites the oldest entry when full (a DPU has bounded SRAM; dropping
//! the oldest telemetry is exactly what real hardware counters do).

#[derive(Debug, Clone)]
pub struct Ring<T> {
    buf: Vec<T>,
    head: usize, // next write position
    len: usize,
    dropped: u64,
}

impl<T: Clone> Ring<T> {
    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0);
        Ring { buf: Vec::with_capacity(cap), head: 0, len: 0, dropped: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of entries overwritten before they were read.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    #[inline]
    pub fn push(&mut self, x: T) {
        let cap = self.buf.capacity();
        if self.buf.len() < cap {
            self.buf.push(x);
        } else {
            self.buf[self.head] = x;
            if self.len == cap {
                self.dropped += 1;
            }
        }
        self.head = (self.head + 1) % cap;
        if self.len < cap {
            self.len += 1;
        }
    }

    /// Iterate oldest -> newest.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        let cap = self.buf.capacity().max(1);
        let start = if self.len == self.buf.len() && self.len == cap {
            self.head
        } else {
            0
        };
        (0..self.len).map(move |i| &self.buf[(start + i) % cap.max(1)])
    }

    /// The most recent entry, if any.
    pub fn last(&self) -> Option<&T> {
        if self.len == 0 {
            return None;
        }
        let cap = self.buf.capacity();
        let idx = (self.head + cap - 1) % cap;
        Some(&self.buf[idx])
    }

    /// Drain everything (oldest -> newest), leaving the ring empty.
    pub fn drain(&mut self) -> Vec<T> {
        let out: Vec<T> = self.iter().cloned().collect();
        self.clear();
        out
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_below_capacity_keeps_order() {
        let mut r = Ring::with_capacity(8);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.iter().cloned().collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert_eq!(r.len(), 5);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn overwrites_oldest_when_full() {
        let mut r = Ring::with_capacity(4);
        for i in 0..7 {
            r.push(i);
        }
        assert_eq!(r.iter().cloned().collect::<Vec<_>>(), vec![3, 4, 5, 6]);
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 3);
    }

    #[test]
    fn last_tracks_most_recent() {
        let mut r = Ring::with_capacity(3);
        assert!(r.last().is_none());
        r.push(10);
        assert_eq!(*r.last().unwrap(), 10);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(*r.last().unwrap(), 4);
    }

    #[test]
    fn drain_empties() {
        let mut r = Ring::with_capacity(4);
        for i in 0..6 {
            r.push(i);
        }
        let v = r.drain();
        assert_eq!(v, vec![2, 3, 4, 5]);
        assert!(r.is_empty());
        r.push(99);
        assert_eq!(*r.last().unwrap(), 99);
    }

    #[test]
    fn exact_capacity_boundary() {
        let mut r = Ring::with_capacity(3);
        for i in 0..3 {
            r.push(i);
        }
        assert_eq!(r.iter().cloned().collect::<Vec<_>>(), vec![0, 1, 2]);
        r.push(3);
        assert_eq!(r.iter().cloned().collect::<Vec<_>>(), vec![1, 2, 3]);
    }
}
