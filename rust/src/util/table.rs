//! Fixed-width ASCII table renderer for the paper-style runbook reports.

/// A table under construction: header row + data rows.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                s.push(' ');
                s.push_str(cell);
                s.push_str(&" ".repeat(widths[i] - cell.len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format nanoseconds human-readably (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.1}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Format a rate (per second) human-readably.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2}G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2}M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2}k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1}/s")
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}KB", b / 1e3)
    } else {
        format!("{b:.0}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["id", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // all bordered lines equal width
        let w = lines[1].len();
        for l in &lines[1..] {
            assert_eq!(l.len(), w, "line {l:?}");
        }
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.5us");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
        assert_eq!(fmt_rate(2_000_000.0), "2.00M/s");
        assert_eq!(fmt_bytes(1_500.0), "1.5KB");
    }
}
