//! Tiny `key=value` line-format parser — used for `artifacts/MANIFEST.txt`
//! and experiment config files (serde/toml are not vendored offline).
//!
//! Format: one `key=value` per line; `#` starts a comment; repeated keys
//! accumulate (used for `param=` and `artifact=` lists).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct KvFile {
    map: BTreeMap<String, Vec<String>>,
    order: Vec<(String, String)>,
}

#[derive(Debug)]
pub enum KvError {
    MissingEquals(usize, String),
    MissingKey(String),
    BadValue(String, String, String),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::MissingEquals(line, text) => write!(f, "line {line}: missing '=' in {text:?}"),
            KvError::MissingKey(key) => write!(f, "missing required key {key:?}"),
            KvError::BadValue(key, val, err) => {
                write!(f, "key {key:?}: invalid value {val:?}: {err}")
            }
        }
    }
}

impl std::error::Error for KvError {}

impl KvFile {
    pub fn parse(text: &str) -> Result<KvFile, KvError> {
        let mut kv = KvFile::default();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| KvError::MissingEquals(i + 1, line.to_string()))?;
            let (key, val) = (key.trim().to_string(), val.trim().to_string());
            kv.map.entry(key.clone()).or_default().push(val.clone());
            kv.order.push((key, val));
        }
        Ok(kv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).and_then(|v| v.first()).map(String::as_str)
    }

    pub fn require(&self, key: &str) -> Result<&str, KvError> {
        self.get(key).ok_or_else(|| KvError::MissingKey(key.to_string()))
    }

    pub fn get_all(&self, key: &str) -> &[String] {
        self.map.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn require_usize(&self, key: &str) -> Result<usize, KvError> {
        let raw = self.require(key)?;
        raw.parse().map_err(|e: std::num::ParseIntError| {
            KvError::BadValue(key.to_string(), raw.to_string(), e.to_string())
        })
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, KvError> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|e: std::num::ParseFloatError| {
                KvError::BadValue(key.to_string(), raw.to_string(), e.to_string())
            }),
        }
    }

    /// Ordered (key, value) pairs as they appeared.
    pub fn entries(&self) -> &[(String, String)] {
        &self.order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_accumulates() {
        let kv = KvFile::parse("a=1\n# comment\nb = two \nparam=x:1\nparam=y:2\n").unwrap();
        assert_eq!(kv.get("a"), Some("1"));
        assert_eq!(kv.get("b"), Some("two"));
        assert_eq!(kv.get_all("param"), &["x:1".to_string(), "y:2".to_string()]);
        assert_eq!(kv.require_usize("a").unwrap(), 1);
    }

    #[test]
    fn missing_equals_is_error() {
        assert!(KvFile::parse("bogus line").is_err());
    }

    #[test]
    fn missing_key_is_error() {
        let kv = KvFile::parse("a=1").unwrap();
        assert!(kv.require("zz").is_err());
        assert!(kv.require_usize("zz").is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let kv = KvFile::parse("a=xyz").unwrap();
        assert!(kv.require_usize("a").is_err());
        assert!(kv.get_f64("a", 0.0).is_err());
        assert_eq!(kv.get_f64("nope", 1.5).unwrap(), 1.5);
    }
}
