//! Scoped-thread fan-out for embarrassingly parallel work (rayon is not
//! vendored offline). Deterministic: results come back in input order
//! regardless of which worker ran which item, so parallel callers produce
//! byte-identical reports across runs and thread counts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count the platform advertises (fallback 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The worker count [`parallel_map`] will actually use for `items` work
/// items when asked for `threads` (0 = auto). Exposed so callers can report
/// the real pool size without duplicating the clamping policy.
pub fn resolve_threads(threads: usize, items: usize) -> usize {
    let n = if threads == 0 { available_threads() } else { threads };
    n.min(items.max(1))
}

/// Apply `f` to every item on a pool of scoped workers; results are returned
/// in input order. `threads == 0` means auto (one worker per core); a single
/// worker degenerates to a plain serial map with zero thread overhead.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = resolve_threads(threads, items.len());
    if n <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|s| {
        for _ in 0..n {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                slots.lock().unwrap().push((i, r));
            });
        }
    });
    let mut v = slots.into_inner().unwrap();
    v.sort_by_key(|&(i, _)| i);
    v.into_iter().map(|(_, r)| r).collect()
}

/// [`parallel_map`] over owned items: each item is *moved* into exactly one
/// worker's `f` call (the snapshot runner hands whole cell groups, configs
/// included, to workers without cloning). Results return in input order;
/// `threads == 0` means auto and a single worker is a plain serial map.
pub fn parallel_map_owned<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = resolve_threads(threads, items.len());
    if n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let len = items.len();
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(len));
    std::thread::scope(|s| {
        for _ in 0..n {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= len {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("item claimed once");
                let r = f(item);
                out.lock().unwrap().push((i, r));
            });
        }
    });
    let mut v = out.into_inner().unwrap();
    v.sort_by_key(|&(i, _)| i);
    v.into_iter().map(|(_, r)| r).collect()
}

/// Apply `f` to every item of a mutable slice on scoped workers, returning
/// the per-item results in input order. The slice is split into contiguous
/// chunks (one per worker) so each item is mutated by exactly one thread;
/// results are concatenated in chunk order, which is input order. With
/// independent per-item work this is byte-identical to the serial loop for
/// any thread count. `threads == 0` means auto; `1` is a plain serial loop.
pub fn parallel_map_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let len = items.len();
    let n = resolve_threads(threads, len);
    if n <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = (len + n - 1) / n;
    let mut out: Vec<Vec<R>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n);
        for (ci, part) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            handles.push(s.spawn(move || {
                let base = ci * chunk;
                part.iter_mut()
                    .enumerate()
                    .map(|(j, t)| f(base + j, t))
                    .collect::<Vec<R>>()
            }));
        }
        out = handles.into_iter().map(|h| h.join().unwrap()).collect();
    });
    out.into_iter().flatten().collect()
}

/// [`parallel_map_mut`] over two equal-length mutable slices zipped
/// item-wise (the telemetry-bus buffer + its node's agent). Both slices use
/// the same chunk boundaries, so item `i` of each is visited together by
/// one worker.
pub fn parallel_zip_mut<A, B, R, F>(a: &mut [A], b: &mut [B], threads: usize, f: F) -> Vec<R>
where
    A: Send,
    B: Send,
    R: Send,
    F: Fn(usize, &mut A, &mut B) -> R + Sync,
{
    assert_eq!(a.len(), b.len(), "parallel_zip_mut: slice lengths differ");
    let len = a.len();
    let n = resolve_threads(threads, len);
    if n <= 1 {
        return a
            .iter_mut()
            .zip(b.iter_mut())
            .enumerate()
            .map(|(i, (x, y))| f(i, x, y))
            .collect();
    }
    let chunk = (len + n - 1) / n;
    let mut out: Vec<Vec<R>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n);
        for (ci, (pa, pb)) in a.chunks_mut(chunk).zip(b.chunks_mut(chunk)).enumerate() {
            let f = &f;
            handles.push(s.spawn(move || {
                let base = ci * chunk;
                pa.iter_mut()
                    .zip(pb.iter_mut())
                    .enumerate()
                    .map(|(j, (x, y))| f(base + j, x, y))
                    .collect::<Vec<R>>()
            }));
        }
        out = handles.into_iter().map(|h| h.join().unwrap()).collect();
    });
    out.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = parallel_map(&items, 8, |&x| x * 3);
        assert_eq!(out, items.iter().map(|&x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..64).collect();
        let f = |&x: &u64| {
            // Non-uniform work so workers finish out of order.
            let mut acc = x;
            for i in 0..(x % 7) * 1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        };
        let serial = parallel_map(&items, 1, f);
        let par = parallel_map(&items, 4, f);
        let auto = parallel_map(&items, 0, f);
        assert_eq!(serial, par);
        assert_eq!(serial, auto);
    }

    #[test]
    fn empty_and_single_item() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn map_owned_moves_items_and_orders_results() {
        for threads in [1, 2, 8, 0] {
            // Box<u64> is not Copy: every item must be moved exactly once.
            let items: Vec<Box<u64>> = (0..103u64).map(Box::new).collect();
            let out = parallel_map_owned(items, threads, |b| *b * 2);
            assert_eq!(out, (0..103u64).map(|x| x * 2).collect::<Vec<_>>(), "threads={threads}");
        }
        assert!(parallel_map_owned(Vec::<u32>::new(), 4, |x| x).is_empty());
    }

    #[test]
    fn map_mut_mutates_every_item_and_orders_results() {
        for threads in [1, 2, 8, 0] {
            let mut items: Vec<u64> = (0..101).collect();
            let out = parallel_map_mut(&mut items, threads, |i, x| {
                *x += 1;
                (*x) * 10 + i as u64 % 10
            });
            assert_eq!(items, (1..102).collect::<Vec<_>>(), "threads={threads}");
            assert_eq!(out.len(), 101);
            let serial: Vec<u64> = (0..101u64).map(|i| (i + 1) * 10 + i % 10).collect();
            assert_eq!(out, serial, "threads={threads}");
        }
    }

    #[test]
    fn zip_mut_pairs_items_by_index() {
        for threads in [1, 3, 8, 0] {
            let mut a: Vec<u64> = (0..67).collect();
            let mut b: Vec<u64> = (0..67).map(|x| x * 100).collect();
            let out = parallel_zip_mut(&mut a, &mut b, threads, |i, x, y| {
                assert_eq!(*y, *x * 100, "zip must pair index {i} items");
                *x += *y;
                *x
            });
            let expect: Vec<u64> = (0..67).map(|x| x + x * 100).collect();
            assert_eq!(a, expect, "threads={threads}");
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn map_mut_empty_slice() {
        let mut items: Vec<u32> = Vec::new();
        assert!(parallel_map_mut(&mut items, 4, |_, x| *x).is_empty());
    }

    #[test]
    fn available_threads_positive() {
        assert!(available_threads() >= 1);
    }

    #[test]
    fn resolve_threads_clamps() {
        assert_eq!(resolve_threads(4, 2), 2);
        assert_eq!(resolve_threads(2, 100), 2);
        assert_eq!(resolve_threads(3, 0), 1);
        assert_eq!(resolve_threads(0, 100), available_threads().min(100));
    }
}
