//! Scoped-thread fan-out for embarrassingly parallel work (rayon is not
//! vendored offline). Deterministic: results come back in input order
//! regardless of which worker ran which item, so parallel callers produce
//! byte-identical reports across runs and thread counts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count the platform advertises (fallback 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The worker count [`parallel_map`] will actually use for `items` work
/// items when asked for `threads` (0 = auto). Exposed so callers can report
/// the real pool size without duplicating the clamping policy.
pub fn resolve_threads(threads: usize, items: usize) -> usize {
    let n = if threads == 0 { available_threads() } else { threads };
    n.min(items.max(1))
}

/// Apply `f` to every item on a pool of scoped workers; results are returned
/// in input order. `threads == 0` means auto (one worker per core); a single
/// worker degenerates to a plain serial map with zero thread overhead.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = resolve_threads(threads, items.len());
    if n <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|s| {
        for _ in 0..n {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                slots.lock().unwrap().push((i, r));
            });
        }
    });
    let mut v = slots.into_inner().unwrap();
    v.sort_by_key(|&(i, _)| i);
    v.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = parallel_map(&items, 8, |&x| x * 3);
        assert_eq!(out, items.iter().map(|&x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..64).collect();
        let f = |&x: &u64| {
            // Non-uniform work so workers finish out of order.
            let mut acc = x;
            for i in 0..(x % 7) * 1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        };
        let serial = parallel_map(&items, 1, f);
        let par = parallel_map(&items, 4, f);
        let auto = parallel_map(&items, 0, f);
        assert_eq!(serial, par);
        assert_eq!(serial, auto);
    }

    #[test]
    fn empty_and_single_item() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn available_threads_positive() {
        assert!(available_threads() >= 1);
    }

    #[test]
    fn resolve_threads_clamps() {
        assert_eq!(resolve_threads(4, 2), 2);
        assert_eq!(resolve_threads(2, 100), 2);
        assert_eq!(resolve_threads(3, 0), 1);
        assert_eq!(resolve_threads(0, 100), available_threads().min(100));
    }
}
