//! In-repo property-testing harness.
//!
//! `proptest` is not vendored in this offline environment, so we provide the
//! same methodology with a small engine: N deterministic seeded cases, a
//! generator context over [`Rng`], and on failure a report of the exact seed
//! that reproduces the case (re-run by pinning `PropConfig::only_seed`).
//! Shrinking is approximated by re-running failures at reduced size classes.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: u64,
    pub seed: u64,
    /// When set, run exactly this case seed (failure reproduction).
    pub only_seed: Option<u64>,
    /// Size classes for coarse shrinking: on failure at size s, retry the
    /// property at each smaller size to report the smallest failing class.
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xD1CE, only_seed: None, max_size: 64 }
    }
}

impl PropConfig {
    pub fn cases(mut self, n: u64) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Per-case generator context: an Rng plus a size class for scaling inputs.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    /// A vector of length <= size scaled by the case's size class.
    pub fn vec_of<T>(&mut self, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let n = self.rng.index(self.size.max(1)) + 1;
        (0..n).map(|_| f(&mut self.rng)).collect()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }
}

/// Run `property` over `cfg.cases` generated cases; panic with a reproducible
/// seed on the first failure. The property returns `Result<(), String>`.
pub fn check(
    name: &str,
    cfg: PropConfig,
    mut property: impl FnMut(&mut Gen) -> Result<(), String>,
) {
    let run_one = |case_seed: u64, size: usize, property: &mut dyn FnMut(&mut Gen) -> Result<(), String>| {
        let mut g = Gen { rng: Rng::new(case_seed, 7), size };
        property(&mut g)
    };

    if let Some(seed) = cfg.only_seed {
        if let Err(msg) = run_one(seed, cfg.max_size, &mut property) {
            panic!("property '{name}' failed (pinned seed {seed}): {msg}");
        }
        return;
    }

    for i in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15);
        // Grow size classes over the run: early cases are small.
        let size = 1 + (cfg.max_size - 1) * i as usize / cfg.cases.max(1) as usize;
        if let Err(msg) = run_one(case_seed, size, &mut property) {
            // Coarse shrink: find the smallest size class that still fails
            // with this seed.
            let mut min_fail = (size, msg.clone());
            for s in 1..size {
                if let Err(m2) = run_one(case_seed, s, &mut property) {
                    min_fail = (s, m2);
                    break;
                }
            }
            panic!(
                "property '{name}' failed on case {i} (seed {case_seed}, size {}): {}\n\
                 reproduce with PropConfig {{ only_seed: Some({case_seed}), .. }}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", PropConfig::default().cases(32), |g| {
            count += 1;
            let v = g.vec_of(|r| r.f64());
            prop_assert!(!v.is_empty(), "empty");
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'sum-small' failed")]
    fn failing_property_reports_seed() {
        check("sum-small", PropConfig::default().cases(64), |g| {
            let v = g.vec_of(|r| r.f64());
            prop_assert!(v.len() < 20, "len {} >= 20", v.len());
            Ok(())
        });
    }

    #[test]
    fn sizes_grow_over_run() {
        let mut max_seen = 0usize;
        check("observe-size", PropConfig::default().cases(64), |g| {
            max_seen = max_seen.max(g.size);
            Ok(())
        });
        assert!(max_seen > 32);
    }
}
