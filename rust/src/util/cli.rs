//! Tiny argv helpers shared by the CLI binary and the bench mains (clap is
//! not vendored offline). Flags are exact matches; values are positional
//! (`--name value`).

/// Is the exact flag present?
pub fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// The value following `--name`, if any.
pub fn opt_val(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// The value following `--name`, parsed, if present and well-formed.
pub fn opt_parse<T: std::str::FromStr>(args: &[String], name: &str) -> Option<T> {
    opt_val(args, name).and_then(|v| v.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn flags_and_values() {
        let args = argv(&["--mitigate", "--replicates", "3", "--rate", "1.5"]);
        assert!(flag(&args, "--mitigate"));
        assert!(!flag(&args, "--real"));
        assert_eq!(opt_val(&args, "--replicates").as_deref(), Some("3"));
        assert_eq!(opt_parse::<usize>(&args, "--replicates"), Some(3));
        assert_eq!(opt_parse::<f64>(&args, "--rate"), Some(1.5));
        assert_eq!(opt_parse::<u64>(&args, "--rate"), None); // malformed
        assert_eq!(opt_val(&args, "--missing"), None);
    }

    #[test]
    fn value_at_end_is_none() {
        let args = argv(&["--seed"]);
        assert_eq!(opt_val(&args, "--seed"), None);
    }
}
