//! Tiny argv helpers shared by the CLI binary and the bench mains (clap is
//! not vendored offline). Flags are exact matches; values are positional
//! (`--name value`).
//!
//! The [`CLI`] table is the single source of truth for `dpulens`'s
//! subcommands and flags: `main.rs` renders its usage text from it, and the
//! binary's `help_covers_every_parsed_flag` test audits it against the
//! flags the command handlers actually parse — so help text can no longer
//! drift from the parser (the PR-3 `--threads`/`--json-out` drift).

/// One flag a subcommand accepts. `value` names the flag's argument in the
/// usage text (None for boolean switches).
#[derive(Debug, Clone, Copy)]
pub struct FlagSpec {
    pub name: &'static str,
    pub value: Option<&'static str>,
}

/// One `dpulens` subcommand: its usage line and full flag set.
#[derive(Debug, Clone, Copy)]
pub struct CmdSpec {
    pub name: &'static str,
    pub summary: &'static str,
    pub flags: &'static [FlagSpec],
}

const fn f(name: &'static str) -> FlagSpec {
    FlagSpec { name, value: None }
}

const fn fv(name: &'static str, value: &'static str) -> FlagSpec {
    FlagSpec { name, value: Some(value) }
}

/// Flags shared by every scenario-driving subcommand (`base_cfg`).
const BASE_FLAGS: [FlagSpec; 5] = [
    fv("--duration-ms", "N"),
    fv("--rate", "R"),
    fv("--seed", "S"),
    fv("--profile", "NAME"),
    f("--mitigate"),
];

/// The dpulens subcommand registry (usage text renders from this).
pub const CLI: &[CmdSpec] = &[
    CmdSpec {
        name: "serve",
        summary: "run one serving scenario",
        flags: &[
            f("--real"),
            fv("--duration-ms", "N"),
            fv("--rate", "R"),
            fv("--seed", "S"),
            fv("--profile", "NAME"),
            f("--mitigate"),
        ],
    },
    CmdSpec {
        name: "inject <COND>",
        summary: "inject one condition, report detection + impact",
        flags: &BASE_FLAGS,
    },
    CmdSpec {
        name: "sweep",
        summary: "all 28 condition experiments in parallel",
        flags: &[
            fv("--duration-ms", "N"),
            fv("--rate", "R"),
            fv("--seed", "S"),
            fv("--profile", "NAME"),
            f("--mitigate"),
            fv("--threads", "N"),
        ],
    },
    CmdSpec {
        name: "matrix",
        summary: "injection x detection scorecard matrix",
        flags: &[
            fv("--replicates", "N"),
            fv("--threads", "N"),
            f("--json"),
            fv("--json-out", "PATH"),
            f("--no-negative-control"),
            f("--no-reuse"),
            fv("--duration-ms", "N"),
            fv("--rate", "R"),
            fv("--seed", "S"),
            fv("--profile", "NAME"),
            f("--mitigate"),
        ],
    },
    CmdSpec {
        name: "fleet",
        summary: "replicas x routing-policy sweep + DP/PD studies (+ multi-pool via pool flags)",
        flags: &[
            fv("--replicas", "N"),
            fv("--threads", "N"),
            f("--json"),
            fv("--json-out", "PATH"),
            fv("--duration-ms", "N"),
            fv("--seed", "S"),
            f("--disagg"),
            fv("--prefill-pools", "K"),
            fv("--decode-pools", "M"),
            f("--telemetry-faults"),
            f("--no-reuse"),
        ],
    },
    CmdSpec {
        name: "campaign <MANIFEST>",
        summary: "run a manifest's workload x topology x condition permutations",
        flags: &[fv("--threads", "N"), f("--json"), fv("--json-out", "PATH"), f("--no-reuse")],
    },
    CmdSpec {
        name: "perf",
        summary: "telemetry-pipeline benchmark (BENCH_pipeline.json)",
        flags: &[
            f("--quick"),
            f("--micro-only"),
            f("--fleet-stress"),
            fv("--replicates", "N"),
            fv("--replicas", "N"),
            fv("--threads", "N"),
            fv("--json-out", "PATH"),
        ],
    },
    CmdSpec {
        name: "conditions",
        summary: "render the condition catalog (table, markdown, or JSON)",
        flags: &[f("--md"), f("--json"), fv("--json-out", "PATH")],
    },
    CmdSpec { name: "runbook", summary: "print the encoded runbook tables", flags: &[] },
    CmdSpec { name: "signals", summary: "print the Table 2(b) signal inventory", flags: &[] },
    CmdSpec {
        name: "attribution <COND>",
        summary: "inject + show root-cause attribution",
        flags: &BASE_FLAGS,
    },
];

/// Look up a subcommand's spec by its bare name (`fleet`, not `fleet ...`).
pub fn cmd_spec(name: &str) -> Option<&'static CmdSpec> {
    CLI.iter().find(|c| c.name == name || c.name.starts_with(&format!("{name} ")))
}

/// Render the full usage text from the [`CLI`] registry.
pub fn usage() -> String {
    let mut s = String::from(
        "dpulens — DPU-vantage observability for LLM inference clusters\n\
         usage: dpulens <subcommand> [flags]\n",
    );
    for c in CLI {
        s.push_str(&format!("  {:<20} {}\n", c.name, c.summary));
        if !c.flags.is_empty() {
            let rendered: Vec<String> = c
                .flags
                .iter()
                .map(|fl| match fl.value {
                    Some(v) => format!("{} {v}", fl.name),
                    None => fl.name.to_string(),
                })
                .collect();
            s.push_str(&format!("  {:<20}   {}\n", "", rendered.join(" ")));
        }
    }
    s
}

/// Is the exact flag present?
pub fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// The value following `--name`, if any.
pub fn opt_val(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// The value following `--name`, parsed, if present and well-formed.
pub fn opt_parse<T: std::str::FromStr>(args: &[String], name: &str) -> Option<T> {
    opt_val(args, name).and_then(|v| v.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn flags_and_values() {
        let args = argv(&["--mitigate", "--replicates", "3", "--rate", "1.5"]);
        assert!(flag(&args, "--mitigate"));
        assert!(!flag(&args, "--real"));
        assert_eq!(opt_val(&args, "--replicates").as_deref(), Some("3"));
        assert_eq!(opt_parse::<usize>(&args, "--replicates"), Some(3));
        assert_eq!(opt_parse::<f64>(&args, "--rate"), Some(1.5));
        assert_eq!(opt_parse::<u64>(&args, "--rate"), None); // malformed
        assert_eq!(opt_val(&args, "--missing"), None);
    }

    #[test]
    fn value_at_end_is_none() {
        let args = argv(&["--seed"]);
        assert_eq!(opt_val(&args, "--seed"), None);
    }

    #[test]
    fn usage_renders_every_spec_flag() {
        let u = usage();
        for c in CLI {
            let bare = c.name.split_whitespace().next().unwrap();
            assert!(u.contains(bare), "usage missing subcommand {bare}");
            for fl in c.flags {
                assert!(u.contains(fl.name), "usage missing {} for {}", fl.name, c.name);
            }
        }
    }

    #[test]
    fn cmd_spec_lookup_handles_positional_args() {
        assert_eq!(cmd_spec("fleet").unwrap().name, "fleet");
        assert_eq!(cmd_spec("inject").unwrap().name, "inject <COND>");
        assert!(cmd_spec("nope").is_none());
        // Every spec is reachable by its bare name.
        for c in CLI {
            let bare = c.name.split_whitespace().next().unwrap();
            assert!(cmd_spec(bare).is_some(), "{bare} unreachable");
        }
    }

    #[test]
    fn flag_names_are_well_formed_and_unique_per_command() {
        for c in CLI {
            let mut seen = std::collections::HashSet::new();
            for fl in c.flags {
                assert!(fl.name.starts_with("--"), "{} malformed", fl.name);
                assert!(seen.insert(fl.name), "{} duplicated in {}", fl.name, c.name);
            }
        }
    }
}
