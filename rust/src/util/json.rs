//! Minimal JSON writer (serde is not vendored offline). Output-only: metrics
//! exports, bench results, experiment records. No parsing.

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a field (object variant only).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, val: impl Into<Json>) {
        if let Json::Arr(ref mut items) = self {
            items.push(val.into());
        } else {
            panic!("push() on non-array Json");
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_object() {
        let j = Json::obj()
            .set("name", "dpulens")
            .set("count", 3u64)
            .set("ok", true)
            .set("ratio", 0.5)
            .set("tags", Json::Arr(vec!["a".into(), "b".into()]));
        assert_eq!(
            j.render(),
            r#"{"name":"dpulens","count":3,"ok":true,"ratio":0.5,"tags":["a","b"]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn array_push() {
        let mut a = Json::arr();
        a.push(1i64);
        a.push("x");
        assert_eq!(a.render(), r#"[1,"x"]"#);
    }
}
