//! Counting global allocator — the fleet-stress bench's peak-RSS proxy.
//!
//! A thin wrapper over [`std::alloc::System`] that keeps three relaxed
//! atomic counters: cumulative bytes allocated, live bytes, and the
//! high-water mark of live bytes. The binary registers it as the
//! `#[global_allocator]` (in `main.rs` only — library unit tests run on the
//! default allocator and read zeros, so tests assert on field *presence*,
//! not positivity).
//!
//! Counters are a proxy, not an RSS measurement: they track what the
//! program asked the allocator for, ignoring allocator slack, fragmentation,
//! and non-heap mappings. For a bench curve that only needs to show "the
//! steady-state observe path allocates nothing", that is exactly the right
//! instrument — it moves by zero when the arena/reuse paths hold.
//!
//! Ordering is `Relaxed` throughout: the counters are statistics, never
//! synchronization, and the bench reads them from the single driver thread
//! after worker scopes have joined (the join is the happens-before edge).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATED: AtomicU64 = AtomicU64::new(0);
static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

/// The counting allocator. Register with
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc;`.
pub struct CountingAlloc;

/// One snapshot of the allocation counters, in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocStats {
    /// Cumulative bytes ever allocated (monotone).
    pub allocated: u64,
    /// Bytes currently live (allocated minus freed).
    pub live: u64,
    /// High-water mark of `live` since the last [`reset_peak`].
    pub peak: u64,
}

/// Read the counters. All zeros when [`CountingAlloc`] is not the
/// registered global allocator (library unit tests).
pub fn stats() -> AllocStats {
    AllocStats {
        allocated: ALLOCATED.load(Ordering::Relaxed),
        live: LIVE.load(Ordering::Relaxed),
        peak: PEAK.load(Ordering::Relaxed),
    }
}

/// Restart the high-water mark from the current live volume — called at the
/// start of each bench point so `peak` reports that point's own excursion,
/// not a predecessor's.
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

fn on_alloc(size: u64) {
    ALLOCATED.fetch_add(size, Ordering::Relaxed);
    let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
    // Lock-free max: racing updates may each retry, but the final value is
    // the true maximum of every observed `live`.
    let mut peak = PEAK.load(Ordering::Relaxed);
    while live > peak {
        match PEAK.compare_exchange_weak(peak, live, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(cur) => peak = cur,
        }
    }
}

fn on_dealloc(size: u64) {
    LIVE.fetch_sub(size, Ordering::Relaxed);
}

// SAFETY: delegates every allocation verbatim to `System`; the counters are
// pure bookkeeping and never influence pointer values or layouts.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size() as u64);
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            on_dealloc(layout.size() as u64);
            on_alloc(new_size as u64);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The test binary does not register the allocator, so the counters stay
    // wherever direct calls put them — exercise the bookkeeping directly.
    #[test]
    fn counters_track_alloc_and_peak() {
        let before = stats();
        on_alloc(1000);
        on_alloc(500);
        on_dealloc(800);
        let after = stats();
        assert_eq!(after.allocated - before.allocated, 1500);
        assert_eq!(after.live, before.live + 700);
        assert!(after.peak >= before.live + 1500);
        on_dealloc(700);
        reset_peak();
        assert_eq!(stats().peak, stats().live);
    }
}
