//! Wall-clock phase timing for the perf harness (`dpulens perf`, the matrix
//! and fleet runners) plus the feature-gated hot-path probes that let tests
//! assert the zero-copy telemetry pipeline really is zero-copy.
//!
//! Everything here is measurement-only: nothing in this module may influence
//! simulated results (the matrix/fleet JSON stays byte-identical whether or
//! not timing runs). The probes compile to nothing unless the crate is built
//! with `--features perf-probe`.

use std::time::Instant;

/// Wall-clock stopwatch for one pipeline phase: start it at the phase
/// boundary, read `total_ms()` at the end. Deliberately minimal — the perf
/// report carries each phase's duration explicitly.
#[derive(Debug)]
pub struct PhaseTimer {
    t0: Instant,
}

impl PhaseTimer {
    pub fn start() -> Self {
        PhaseTimer { t0: Instant::now() }
    }

    /// Wall-clock since construction, ms.
    pub fn total_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for PhaseTimer {
    fn default() -> Self {
        Self::start()
    }
}

/// Events-per-second from an event count and elapsed milliseconds (0 when
/// the interval is degenerate).
pub fn events_per_sec(events: u64, elapsed_ms: f64) -> f64 {
    if elapsed_ms <= 0.0 {
        0.0
    } else {
        events as f64 * 1e3 / elapsed_ms
    }
}

/// Hot-path instrumentation counters.
///
/// Thread-local so concurrent matrix/fleet worker cells (and parallel test
/// threads) never observe each other's counts: a test drives one scenario on
/// its own thread and reads back exactly that scenario's clone count.
/// Without `--features perf-probe` every function is a no-op that the
/// optimizer deletes.
pub mod probe {
    /// Count one `TelemetryEvent::clone` (called from the manual `Clone`
    /// impl). Zero on the batched bus → agent path unless a recorder ring
    /// is attached.
    #[inline(always)]
    pub fn count_event_clone() {
        #[cfg(feature = "perf-probe")]
        imp::EVENT_CLONES.with(|c| c.set(c.get() + 1));
    }

    /// Telemetry-event clones observed on this thread since the last reset.
    #[cfg(feature = "perf-probe")]
    pub fn event_clones() -> u64 {
        imp::EVENT_CLONES.with(|c| c.get())
    }

    /// Telemetry-event clones observed on this thread since the last reset
    /// (probe disabled: always 0).
    #[cfg(not(feature = "perf-probe"))]
    pub fn event_clones() -> u64 {
        0
    }

    /// Reset this thread's counters.
    pub fn reset() {
        #[cfg(feature = "perf-probe")]
        imp::EVENT_CLONES.with(|c| c.set(0));
    }

    #[cfg(feature = "perf-probe")]
    mod imp {
        use std::cell::Cell;
        thread_local! {
            pub static EVENT_CLONES: Cell<u64> = const { Cell::new(0) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_is_monotone() {
        let t = PhaseTimer::start();
        let a = t.total_ms();
        let b = t.total_ms();
        assert!(a >= 0.0);
        assert!(b >= a);
    }

    #[test]
    fn events_per_sec_handles_degenerate_intervals() {
        assert_eq!(events_per_sec(100, 0.0), 0.0);
        assert_eq!(events_per_sec(100, -1.0), 0.0);
        assert!((events_per_sec(1000, 500.0) - 2000.0).abs() < 1e-9);
    }

    #[cfg(feature = "perf-probe")]
    #[test]
    fn probe_counts_event_clones_per_thread() {
        use crate::ids::{GpuId, NodeId};
        use crate::sim::SimTime;
        use crate::telemetry::event::{TelemetryEvent, TelemetryKind};
        probe::reset();
        let ev = TelemetryEvent {
            t: SimTime(1),
            node: NodeId(0),
            kind: TelemetryKind::Doorbell { gpu: GpuId(0) },
        };
        let before = probe::event_clones();
        let _c = ev.clone();
        assert_eq!(probe::event_clones(), before + 1);
        probe::reset();
        assert_eq!(probe::event_clones(), 0);
    }
}
