//! Multiplicative-hash HashMap for small integer keys on the telemetry hot
//! path. std's default SipHash is DoS-resistant but costs ~2x on per-event
//! map ops; DPU window accumulation hashes trusted internal ids only.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Fibonacci-multiplicative hasher for u32/u64-sized keys.
#[derive(Default)]
pub struct FibHasher {
    state: u64,
}

impl Hasher for FibHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Generic fallback (rare: composite keys).
        for &b in bytes {
            self.state = (self.state ^ b as u64).wrapping_mul(0x9E3779B97F4A7C15);
        }
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.state = (v as u64).wrapping_mul(0x9E3779B97F4A7C15);
        self.state ^= self.state >> 29;
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = v.wrapping_mul(0x9E3779B97F4A7C15);
        self.state ^= self.state >> 29;
    }
}

/// Drop-in HashMap with the fast hasher.
pub type FastMap<K, V> = HashMap<K, V, BuildHasherDefault<FibHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behaves_like_a_map() {
        let mut m: FastMap<u32, u64> = FastMap::default();
        for i in 0..1000u32 {
            m.insert(i, i as u64 * 3);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u32 {
            assert_eq!(m[&i], i as u64 * 3);
        }
        m.retain(|k, _| k % 2 == 0);
        assert_eq!(m.len(), 500);
    }

    #[test]
    fn distributes_sequential_keys() {
        // Fibonacci hashing must spread consecutive ids across buckets.
        let mut h1 = FibHasher::default();
        h1.write_u32(1);
        let mut h2 = FibHasher::default();
        h2.write_u32(2);
        assert_ne!(h1.finish() & 0xFF, h2.finish() & 0xFF);
    }
}
