//! Deterministic PCG-XSH-RR 64/32 random number generator.
//!
//! The `rand` crate is not vendored in this offline environment, and the
//! simulation demands bit-exact reproducibility across runs anyway, so we
//! implement PCG (O'Neill 2014) directly. Every component that needs
//! randomness derives a child stream via [`Rng::fork`], keeping subsystems
//! statistically independent and insulated from each other's draw counts.

const MULT: u64 = 6364136223846793005;

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child stream; deterministic in parent state.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(seed, tag.wrapping_add(1))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64_open().ln() / rate
    }

    /// Log-normal parameterized by the underlying normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto (Lomax-style heavy tail), scale x_m > 0, shape alpha > 0.
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        x_m / self.f64_open().powf(1.0 / alpha)
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Zipf sampler over ranks 1..=n with exponent `s` (precomputed CDF).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a 0-based rank (0 is the most popular).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 1);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn fork_is_deterministic_and_independent() {
        let mut p1 = Rng::seeded(7);
        let mut p2 = Rng::seeded(7);
        let mut c1 = p1.fork(3);
        let mut c2 = p2.fork(3);
        for _ in 0..100 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::seeded(2);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seeded(3);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_rank_ordering() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::seeded(5);
        let mut counts = vec![0u32; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[99]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn pareto_heavy_tail() {
        let mut r = Rng::seeded(8);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.pareto(1.0, 2.0)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let frac_big = xs.iter().filter(|&&x| x > 10.0).count() as f64 / n as f64;
        assert!(frac_big > 0.002 && frac_big < 0.05, "frac={frac_big}");
    }
}
