//! Streaming statistics: Welford accumulators, P² quantile estimation,
//! histograms, and exact-percentile summaries for bench reporting.

/// Numerically stable streaming mean/variance (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Coefficient of variation: std / |mean| (0 when empty/zero-mean).
    pub fn cov(&self) -> f64 {
        let m = self.mean().abs();
        if m < 1e-12 { 0.0 } else { self.std() / m }
    }

    /// Burstiness: max / |mean| — the scorer-kernel feature, natively.
    pub fn burstiness(&self) -> f64 {
        let m = self.mean().abs();
        if m < 1e-12 { 0.0 } else { self.max() / m }
    }

    pub fn spread(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max - self.min }
    }

    /// Merge another accumulator (parallel Welford combine).
    pub fn merge(&mut self, o: &Welford) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = (self.n + o.n) as f64;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n;
        self.m2 += o.m2 + d * d * self.n as f64 * o.n as f64 / n;
        self.n += o.n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// P² (Jain & Chlamtac) single-quantile streaming estimator: O(1) memory.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    q: f64,
    n: [f64; 5],
    np: [f64; 5],
    dn: [f64; 5],
    heights: [f64; 5],
    count: usize,
    init: Vec<f64>,
}

impl P2Quantile {
    pub fn new(q: f64) -> Self {
        assert!((0.0..=1.0).contains(&q));
        P2Quantile {
            q,
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            dn: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            heights: [0.0; 5],
            count: 0,
            init: Vec::with_capacity(5),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if self.init.len() < 5 {
            self.init.push(x);
            if self.init.len() == 5 {
                self.init.sort_by(|a, b| a.partial_cmp(b).unwrap());
                for i in 0..5 {
                    self.heights[i] = self.init[i];
                }
            }
            return;
        }
        // Find cell k.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if self.heights[i] <= x && x < self.heights[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Adjust interior markers with parabolic interpolation.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let s = d.signum();
                let h = self.parabolic(i, s);
                self.heights[i] = if self.heights[i - 1] < h && h < self.heights[i + 1] {
                    h
                } else {
                    self.linear(i, s)
                };
                self.n[i] += s;
            }
        }
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (nm, ni, np1) = (self.n[i - 1], self.n[i], self.n[i + 1]);
        let (hm, hi, hp) = (self.heights[i - 1], self.heights[i], self.heights[i + 1]);
        hi + s / (np1 - nm)
            * ((ni - nm + s) * (hp - hi) / (np1 - ni) + (np1 - ni - s) * (hi - hm) / (ni - nm))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + s * (self.heights[j] - self.heights[i]) / (self.n[j] - self.n[i])
    }

    pub fn value(&self) -> f64 {
        if self.init.len() < 5 {
            if self.init.is_empty() {
                return 0.0;
            }
            let mut v = self.init.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let idx = ((v.len() - 1) as f64 * self.q).round() as usize;
            return v[idx];
        }
        self.heights[2]
    }

    pub fn count(&self) -> usize {
        self.count
    }
}

/// Exact-percentile summary for modest sample counts (bench reporting).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.samples.extend_from_slice(xs);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0 * (v.len() - 1) as f64).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Fixed-bucket histogram with power-of-two-ish bounds, for latency spectra.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Exponential buckets from `lo` growing by `factor`, `n` buckets.
    pub fn exponential(lo: f64, factor: f64, n: usize) -> Self {
        assert!(lo > 0.0 && factor > 1.0 && n > 0);
        let mut bounds = Vec::with_capacity(n);
        let mut b = lo;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram { counts: vec![0; n + 1], bounds, total: 0 }
    }

    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b <= x);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i == 0 {
                    self.bounds[0]
                } else {
                    self.bounds[(i - 1).min(self.bounds.len() - 1)]
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, -1.0, 0.5];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), -1.0);
        assert_eq!(w.max(), 5.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let mut r = Rng::seeded(1);
        let xs: Vec<f64> = (0..1000).map(|_| r.normal()).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 { a.push(x) } else { b.push(x) }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn welford_empty_is_zero() {
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.std(), 0.0);
        assert_eq!(w.cov(), 0.0);
    }

    #[test]
    fn p2_approximates_median() {
        let mut r = Rng::seeded(2);
        let mut p2 = P2Quantile::new(0.5);
        let mut v = Vec::new();
        for _ in 0..20_000 {
            let x = r.normal();
            p2.push(x);
            v.push(x);
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = v[v.len() / 2];
        assert!((p2.value() - exact).abs() < 0.05, "p2={} exact={}", p2.value(), exact);
    }

    #[test]
    fn p2_approximates_p99() {
        let mut r = Rng::seeded(3);
        let mut p2 = P2Quantile::new(0.99);
        let mut v = Vec::new();
        for _ in 0..50_000 {
            let x = r.exponential(1.0);
            p2.push(x);
            v.push(x);
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = v[(0.99 * v.len() as f64) as usize];
        assert!((p2.value() - exact).abs() / exact < 0.15, "p2={} exact={}", p2.value(), exact);
    }

    #[test]
    fn p2_small_sample_fallback() {
        let mut p2 = P2Quantile::new(0.5);
        for &x in &[3.0, 1.0, 2.0] {
            p2.push(x);
        }
        assert_eq!(p2.value(), 2.0);
    }

    #[test]
    fn p2_empty_and_single_sample() {
        // The clone+sort fallback path: no samples -> 0; one sample -> it,
        // at every quantile.
        assert_eq!(P2Quantile::new(0.5).value(), 0.0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let mut p2 = P2Quantile::new(q);
            p2.push(7.5);
            assert_eq!(p2.value(), 7.5, "q={q}");
            assert_eq!(p2.count(), 1);
        }
    }

    #[test]
    fn p2_fallback_quantile_rank_under_five_samples() {
        // Four samples stay on the exact fallback: p99 must pick the max,
        // p0 the min, and the median the upper-middle rank.
        let mut hi = P2Quantile::new(0.99);
        let mut lo = P2Quantile::new(0.0);
        let mut med = P2Quantile::new(0.5);
        for &x in &[40.0, 10.0, 30.0, 20.0] {
            hi.push(x);
            lo.push(x);
            med.push(x);
        }
        assert_eq!(hi.value(), 40.0);
        assert_eq!(lo.value(), 10.0);
        // round(0.5 * 3) = 2 -> third-smallest of four.
        assert_eq!(med.value(), 30.0);
    }

    #[test]
    fn p2_all_duplicates_is_exact() {
        // Identical samples must estimate exactly that value (marker
        // heights collapse; no parabolic drift), across the 5-sample
        // initialization boundary.
        for n in [3usize, 5, 100] {
            let mut p2 = P2Quantile::new(0.9);
            for _ in 0..n {
                p2.push(42.0);
            }
            assert_eq!(p2.value(), 42.0, "n={n}");
            assert_eq!(p2.count(), n);
        }
    }

    #[test]
    fn p2_monotone_input_tracks_the_quantile() {
        // Strictly increasing input 1..=1000: the streaming estimate must
        // land near the true quantile despite the worst-case (sorted)
        // arrival order.
        for q in [0.5, 0.9] {
            let mut p2 = P2Quantile::new(q);
            for i in 1..=1000 {
                p2.push(i as f64);
            }
            let exact = q * 1000.0;
            let rel = (p2.value() - exact).abs() / exact;
            assert!(rel < 0.05, "q={q}: p2={} exact={exact}", p2.value());
            // Estimates stay inside the observed range.
            assert!(p2.value() >= 1.0 && p2.value() <= 1000.0);
        }
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for i in 1..=101 {
            s.push(i as f64);
        }
        assert_eq!(s.p50(), 51.0); // true median of 1..=101
        assert_eq!(s.p99(), 100.0); // rank round(0.99*100)=99 -> value 100
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 101.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::exponential(1.0, 2.0, 20);
        let mut r = Rng::seeded(4);
        for _ in 0..10_000 {
            h.record(r.pareto(1.0, 1.5));
        }
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.999));
        assert_eq!(h.total(), 10_000);
    }
}
