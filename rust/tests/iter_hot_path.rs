//! Steady-state decode hot-path assertions (requires `--features
//! perf-probe`).
//!
//! This binary registers [`dpulens::util::alloc::CountingAlloc`] as its
//! global allocator and asserts an *exact zero* allocation delta over a
//! measured span, so it deliberately holds a single `#[test]` fn: the std
//! harness runs sibling tests on concurrent threads of the same process,
//! and any of their allocations would land in the shared counters
//! mid-measurement. Everything sequential in one body keeps every counted
//! byte attributable.
//!
//! The measured span is the same mid-window design as the `dpulens perf`
//! iteration microbench (`iter_bench_cfg`): warm past arrival/prefill and
//! six full telemetry windows so every reusable buffer — bus lanes, outbox,
//! calendar shards, `IterScratch`, backend staging, egress lanes — reaches
//! its plateau capacity, then bracket a span that contains no window tick,
//! no admission, and no retirement: nothing but decode rounds and their
//! coalesced egress deliveries.

use dpulens::coordinator::perf::iter_bench_cfg;
use dpulens::coordinator::Scenario;
use dpulens::sim::{SimTime, MS};
use dpulens::util::alloc::{stats, CountingAlloc};
use dpulens::util::perf::probe;

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_decode_iterations_allocate_and_clone_nothing() {
    for batch in [64usize, 256] {
        let mut world = Scenario::new(iter_bench_cfg(batch));
        world.run_to(SimTime(122 * MS));
        assert_eq!(
            world.engine.replicas[0].batcher.lanes().len(),
            batch,
            "world must be saturated at batch {batch} before measuring"
        );
        let iters0 = world.iterations_so_far();
        probe::reset();
        let before = stats().allocated;
        world.run_to(SimTime(138 * MS));
        let span_bytes = stats().allocated - before;
        let iters = world.iterations_so_far() - iters0;
        assert!(iters > 0, "measured span ran no decode iterations at batch {batch}");
        assert_eq!(
            world.engine.replicas[0].batcher.lanes().len(),
            batch,
            "a lane retired mid-span at batch {batch}; the span is not steady-state"
        );
        assert_eq!(
            span_bytes, 0,
            "steady-state decode allocated {span_bytes} heap bytes over \
             {iters} iterations at batch {batch}"
        );
        assert_eq!(
            probe::event_clones(),
            0,
            "the decode hot path cloned telemetry events at batch {batch}"
        );
    }
}
