//! Snapshot-and-branch equivalence suite — the headline invariant behind
//! `coordinator::snapshot`: forking experiment cells from a shared
//! pre-injection checkpoint is pure performance work, so every report
//! schema must stay **byte-identical** to from-scratch execution
//! (`--no-reuse`), for any worker-thread count and either calendar backend.
//!
//! * the matrix scorecard renders the same JSON forked and from scratch,
//!   across threads 1/2/8 and under `CalendarKind::Heap`;
//! * a fleet sweep with every study enabled (disagg + multi-pool +
//!   telemetry-faults, schema v4) and a plain v1 sweep both survive the
//!   forked-vs-scratch comparison;
//! * the campaign runner's manifest cells do too;
//! * branches forked from one checkpoint share no state (running one
//!   branch cannot perturb a sibling forked afterwards);
//! * the default-shaped matrix actually reuses: its cells collapse into
//!   few enough prefix groups that at least half the prefix simulation
//!   time is eliminated (`reuse_ratio >= 2`).

use dpulens::coordinator::campaign::{run_campaign, CampaignConfig};
use dpulens::coordinator::experiment::{inject_time, standard_cfg};
use dpulens::coordinator::fleet::{fleet_base_cfg, run_fleet, FleetConfig, MultiPoolSpec};
use dpulens::coordinator::matrix::{run_matrix, MatrixConfig};
use dpulens::coordinator::{Scenario, WorldSnapshot};
use dpulens::dpu::detectors::Condition;
use dpulens::engine::RoutePolicy;
use dpulens::sim::{CalendarKind, SimDur};

/// Trimmed matrix base (matrix_suite's determinism shape): detection
/// success is irrelevant here, only forked-vs-scratch byte equality.
fn trimmed_matrix(threads: usize, no_reuse: bool, calendar: CalendarKind) -> MatrixConfig {
    let mut base = standard_cfg();
    base.duration = SimDur::from_ms(1300);
    base.warmup_windows = 10;
    base.calib_windows = 50;
    base.calendar = calendar;
    MatrixConfig { base, replicates: 1, threads, negative_control: true, no_reuse }
}

#[test]
fn matrix_forked_json_matches_scratch_across_threads() {
    let scratch = run_matrix(&trimmed_matrix(2, true, CalendarKind::Bucket));
    let forked1 = run_matrix(&trimmed_matrix(1, false, CalendarKind::Bucket));
    let forked8 = run_matrix(&trimmed_matrix(8, false, CalendarKind::Bucket));

    let s = scratch.to_json().render();
    assert_eq!(s, forked1.to_json().render(), "forked (1 thread) JSON diverged");
    assert_eq!(s, forked8.to_json().render(), "forked (8 threads) JSON diverged");
    assert!(s.contains("\"schema\":\"dpulens.matrix.v1\""));

    // Scratch mode really ran every cell from scratch...
    assert_eq!(scratch.reuse.forked_branches, 0);
    assert_eq!(scratch.reuse.sim_ns_saved(), 0);
    assert_eq!(scratch.reuse.cells_total, scratch.reuse.prefixes_simulated);
    // ...while the forked sweeps shared prefixes, identically at any
    // thread count (the counters are order-independent sums).
    assert!(forked1.reuse.forked_branches > 0, "no cell forked: {:?}", forked1.reuse);
    assert!(forked1.reuse.prefixes_simulated < forked1.reuse.cells_total);
    assert_eq!(forked1.reuse, forked8.reuse, "reuse counters vary with threads");

    // The acceptance floor: the standard-shaped cells collapse into few
    // enough groups that reuse halves the total prefix simulation time.
    let ratio = forked1.reuse.reuse_ratio();
    assert!(ratio >= 2.0, "reuse ratio {ratio:.2} below 2x: {:?}", forked1.reuse);
}

#[test]
fn matrix_forked_json_matches_scratch_on_the_heap_calendar() {
    let scratch = run_matrix(&trimmed_matrix(2, true, CalendarKind::Heap));
    let forked = run_matrix(&trimmed_matrix(2, false, CalendarKind::Heap));
    assert_eq!(
        scratch.to_json().render(),
        forked.to_json().render(),
        "forked JSON diverged on the heap calendar"
    );
    assert!(forked.reuse.forked_branches > 0);
}

/// Trimmed 2-replica fleet config (telemetry_faults_suite's shape).
fn trimmed_fleet(no_reuse: bool, all_studies: bool) -> FleetConfig {
    let mut base = fleet_base_cfg(2);
    base.duration = SimDur::from_ms(1200);
    base.warmup_windows = 10;
    base.calib_windows = 40;
    FleetConfig {
        base,
        replicas: 2,
        policies: vec![RoutePolicy::FlowHash, RoutePolicy::PowerOfTwo],
        threads: 2,
        disagg: all_studies,
        multipool: if all_studies {
            Some(MultiPoolSpec { replicas: 6, prefill_pools: 2, decode_pools: 1 })
        } else {
            None
        },
        telemetry_faults: all_studies,
        no_reuse,
    }
}

#[test]
fn fleet_v1_forked_json_matches_scratch() {
    let scratch = run_fleet(&trimmed_fleet(true, false));
    let forked = run_fleet(&trimmed_fleet(false, false));
    let s = scratch.to_json().render();
    assert_eq!(s, forked.to_json().render(), "fleet v1 forked JSON diverged");
    assert!(s.contains("\"schema\":\"dpulens.fleet.v1\""));
    assert_eq!(scratch.reuse.forked_branches, 0);
    // The DP condition triples (healthy/injected/mitigated per condition)
    // share their shaped config, so the plain sweep already forks.
    assert!(forked.reuse.forked_branches > 0, "no fleet cell forked: {:?}", forked.reuse);
}

#[test]
fn fleet_v4_all_studies_forked_json_matches_scratch() {
    // Every cell family at once — policy sweep, DP triples, disagg study,
    // multi-pool study, TD telemetry-fault block — through the positional
    // outcome decode. A grouping bug that reordered or dropped one cell
    // would corrupt a section here, not just flip a number.
    let scratch = run_fleet(&trimmed_fleet(true, true));
    let forked = run_fleet(&trimmed_fleet(false, true));
    let s = scratch.to_json().render();
    assert_eq!(s, forked.to_json().render(), "fleet v4 forked JSON diverged");
    assert!(s.contains("\"schema\":\"dpulens.fleet.v4\""));
    assert!(s.contains("\"disagg\""));
    assert!(s.contains("\"multipool\""));
    assert!(s.contains("\"td_conditions\""));
    assert!(forked.reuse.forked_branches > 0);
    assert!(forked.reuse.sim_ns_saved() > 0);
}

#[test]
fn campaign_forked_json_matches_scratch() {
    let text = include_str!("../../examples/campaign_smoke.toml");
    let base = CampaignConfig::parse(text).unwrap();
    let mk = |no_reuse: bool| {
        let mut cc = base.clone();
        cc.threads = 2;
        cc.no_reuse = no_reuse;
        cc
    };
    let scratch = run_campaign(&mk(true));
    let forked = run_campaign(&mk(false));
    let s = scratch.to_json().render();
    assert_eq!(s, forked.to_json().render(), "campaign forked JSON diverged");
    assert!(s.starts_with("{\"schema\":\"dpulens.campaign.v1\""));
    // 2 workloads x (healthy + NS2): each workload's pair shares a prefix.
    assert_eq!(forked.reuse.cells_total, 4);
    assert_eq!(forked.reuse.prefixes_simulated, 2);
    assert_eq!(forked.reuse.forked_branches, 4);
}

#[test]
fn sibling_branches_forked_from_one_checkpoint_stay_isolated() {
    // Integration-level isolation proof on the public API: capture one
    // checkpoint, burn an injected branch first, then fork the healthy
    // branch — it must still match a from-scratch healthy run exactly.
    let mut healthy = standard_cfg();
    healthy.duration = SimDur::from_ms(1300);
    healthy.warmup_windows = 10;
    healthy.calib_windows = 50;
    let at = inject_time(&healthy);
    let mut injected = healthy.clone();
    injected.inject = Some((Condition::Ew6Retransmissions, at));

    let snap = WorldSnapshot::capture(healthy.clone(), at);
    let injected_res = snap.resume_from(injected);
    assert!(injected_res.injected_at.is_some(), "injection never landed");
    let forked_healthy = snap.resume_from(healthy.clone());
    let scratch_healthy = Scenario::new(healthy).run();
    assert_eq!(
        format!("{scratch_healthy:?}"),
        format!("{forked_healthy:?}"),
        "running the injected sibling first perturbed the healthy branch"
    );
}
