//! Cross-module integration: full scenario runs exercising workload →
//! engine → cluster → DPU plane → mitigation, without PJRT (sim backends).

use dpulens::coordinator::experiment::{inject_time, standard_cfg};
use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::dpu::attribution::RootCause;
use dpulens::dpu::detectors::Condition;
use dpulens::engine::preset;
use dpulens::sim::SimDur;
use dpulens::workload::trace;

fn fast_cfg() -> ScenarioCfg {
    let mut cfg = standard_cfg();
    cfg.duration = SimDur::from_ms(2200);
    cfg
}

#[test]
fn pcie_condition_detected_and_attributed_host_local() {
    let mut cfg = fast_cfg();
    cfg.inject = Some((Condition::Pc9RegistrationChurn, inject_time(&cfg)));
    let res = Scenario::new(cfg).run();
    assert!(res.detected(Condition::Pc9RegistrationChurn), "PC9 must fire");
    // Attribution: registration churn is host-local at the entry node.
    assert!(
        res.attributions
            .iter()
            .any(|a| matches!(a.cause, RootCause::HostLocal(_))),
        "expected HostLocal attribution, got {:?}",
        res.attributions.iter().map(|a| &a.cause).collect::<Vec<_>>()
    );
}

#[test]
fn fabric_condition_attributed_network_side() {
    let mut cfg = fast_cfg();
    cfg.inject = Some((Condition::Ew7CreditStarvation, inject_time(&cfg)));
    let res = Scenario::new(cfg).run();
    assert!(res.detected(Condition::Ew7CreditStarvation));
    assert!(
        res.attributions.iter().any(|a| a.cause == RootCause::NetworkSide),
        "{:?}",
        res.attributions.iter().map(|a| &a.cause).collect::<Vec<_>>()
    );
}

#[test]
fn straggler_with_pcie_vantage_attributed_locally() {
    // §4.2: EW skew + PCIe-vantage corroboration => local, not network.
    let mut cfg = fast_cfg();
    cfg.engine.profile = preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 150.0 };
    cfg.inject = Some((Condition::Pc4IntraNodeSkew, inject_time(&cfg)));
    let res = Scenario::new(cfg).run();
    assert!(res.detected(Condition::Pc4IntraNodeSkew), "PC4 must fire");
}

#[test]
fn mitigation_improves_throughput_under_fabric_loss() {
    let mut inj = fast_cfg();
    inj.inject = Some((Condition::Ew6Retransmissions, inject_time(&inj)));
    let faulted = Scenario::new(inj.clone()).run();
    let mut mit = inj;
    mit.mitigate = true;
    let healed = Scenario::new(mit).run();
    assert!(!healed.actions.is_empty(), "controller must act");
    // Mitigation must not make things worse, and usually helps p99.
    assert!(
        healed.metrics.tok_per_s() >= faulted.metrics.tok_per_s() * 0.95,
        "healed {} vs faulted {}",
        healed.metrics.tok_per_s(),
        faulted.metrics.tok_per_s()
    );
}

#[test]
fn static_batching_hurts_under_bimodal_lengths() {
    // Table 2(a)/NS8 shape: continuous+remap beats static batching when
    // output lengths are bimodal.
    let mut base = fast_cfg();
    base.duration = SimDur::from_ms(1800);
    // Saturate decode slots: policy differences only matter under load.
    base.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 2500.0 };
    base.workload.prompt_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
    base.workload.output_len =
        dpulens::sim::dist::LengthDist::Bimodal { short: 2, long: 32, p_short: 0.5 };
    let cont = Scenario::new(base.clone()).run();
    let mut stat = base;
    stat.engine.policy.continuous = false;
    stat.engine.policy.inflight_remap = false;
    let stat_res = Scenario::new(stat).run();
    // When demand fits capacity both policies eventually emit the same
    // tokens; the cost of static batching is LATENCY — queued requests wait
    // for full batch drains. (Throughput must still not regress.)
    assert!(
        cont.metrics.tok_per_s() >= stat_res.metrics.tok_per_s() * 0.99,
        "continuous tput regressed: {} vs {}",
        cont.metrics.tok_per_s(),
        stat_res.metrics.tok_per_s()
    );
    assert!(
        cont.metrics.ttft_ns.p99() < stat_res.metrics.ttft_ns.p99(),
        "continuous p99 TTFT {} !< static {}",
        cont.metrics.ttft_ns.p99(),
        stat_res.metrics.ttft_ns.p99()
    );
}

#[test]
fn trace_replay_reproduces_workload_shape() {
    let spec = dpulens::workload::WorkloadSpec::default();
    let mut g = dpulens::workload::WorkloadGen::new(spec, 2048, 5);
    let reqs = g.take(50);
    let rows = trace::record(&reqs);
    let replayed = trace::replay(&rows, 2048);
    assert_eq!(replayed.len(), 50);
    for (a, b) in reqs.iter().zip(&replayed) {
        assert_eq!(a.arrival, b.arrival);
        assert_eq!(a.prompt_len(), b.prompt_len());
    }
}

#[test]
fn run_results_are_bitwise_deterministic() {
    let mut cfg = fast_cfg();
    cfg.duration = SimDur::from_ms(1600);
    cfg.inject = Some((Condition::Pc5PcieSaturation, inject_time(&cfg)));
    let a = Scenario::new(cfg.clone()).run();
    let b = Scenario::new(cfg).run();
    assert_eq!(a.metrics.completed, b.metrics.completed);
    assert_eq!(a.metrics.tokens_out, b.metrics.tokens_out);
    assert_eq!(a.telemetry_published, b.telemetry_published);
    assert_eq!(a.detections.len(), b.detections.len());
    for (x, y) in a.detections.iter().zip(&b.detections) {
        assert_eq!(x.condition, y.condition);
        assert_eq!(x.at, y.at);
    }
}

#[test]
fn telemetry_conservation_holds() {
    let res = Scenario::new(fast_cfg()).run();
    assert_eq!(
        res.dpu_ingested + res.dpu_invisible_dropped,
        res.telemetry_published,
        "every event is either DPU-visible or filtered by §4.3"
    );
    // The serving path produced real work.
    assert!(res.metrics.completed > 50);
    assert!(res.metrics.tokens_out > 200);
    assert!(res.iterations > res.metrics.completed as u64);
}
