//! Egress-coalescing equivalence (runs in the perf-probe tier beside
//! `iter_hot_path`, the other half of the iteration hot-path contract).
//!
//! `ScenarioCfg::per_token_egress = true` replays the legacy scheduling —
//! one calendar event per generated token — while the default path arms a
//! single `Ev::EgressBatch` per iteration whose lane replays each token
//! completion at the exact `(time, seq)` calendar key the legacy event
//! would have carried. Equivalence is therefore total: every field of the
//! result bundle (metrics, detections, conservation counters, per-replica
//! accounting) must match byte for byte, on both calendar backends.
//!
//! The schedules are deliberately tie-heavy: arrival rates near capacity
//! with short outputs make many events share timestamps (egress completions
//! against iteration boundaries, window ticks, and each other), so the
//! sequence-number tiebreak — the part the coalesced lane must reproduce
//! exactly — decides pop order constantly.

use dpulens::coordinator::fleet::{disagg_base_cfg, fleet_base_cfg};
use dpulens::coordinator::{RunResult, Scenario, ScenarioCfg};
use dpulens::sim::dist::{Arrival, LengthDist};
use dpulens::sim::{CalendarKind, SimDur};

/// Deterministic fingerprint over the result bundle. `class_counts` is the
/// one HashMap-keyed field (iteration order varies run to run), so fold it
/// through a sorted view instead of `{:?}`.
fn digest(r: &RunResult) -> String {
    let mut classes: Vec<_> = r.class_counts.iter().map(|(k, v)| (*k, *v)).collect();
    classes.sort_unstable();
    format!(
        "{:?}|{:?}|{:?}|{:?}|{:?}|{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{:?}|{:?}|{}|{}|{:?}",
        r.metrics,
        r.tenants,
        r.detections,
        r.sw_alarm_log,
        r.actions,
        r.telemetry_published,
        r.dpu_ingested,
        r.dpu_invisible_dropped,
        r.windows,
        r.iterations,
        r.replica_iterations,
        r.replica_routed,
        r.replica_kv_peak,
        r.handoffs,
        classes,
        r.requests_arrived,
        r.handoffs_parked_at_end,
        r.ladder_transitions,
    )
}

fn run_with(mut cfg: ScenarioCfg, per_token: bool, calendar: CalendarKind) -> RunResult {
    cfg.per_token_egress = per_token;
    cfg.calendar = calendar;
    Scenario::new(cfg).run()
}

/// All four mode combinations of one scenario must produce one digest.
fn assert_equivalent(mk: impl Fn() -> ScenarioCfg, label: &str) {
    let baseline = run_with(mk(), true, CalendarKind::Bucket);
    assert!(
        baseline.metrics.completed > 0,
        "{label}: baseline world served no requests; equivalence would be vacuous"
    );
    assert!(baseline.telemetry_published > 1_000, "{label}: run too small to be meaningful");
    let want = digest(&baseline);
    let coalesced = digest(&run_with(mk(), false, CalendarKind::Bucket));
    assert_eq!(want, coalesced, "{label}: coalesced egress diverged on the bucket calendar");
    let heap_legacy = digest(&run_with(mk(), true, CalendarKind::Heap));
    assert_eq!(want, heap_legacy, "{label}: legacy egress diverged on the heap calendar");
    let heap_coalesced = digest(&run_with(mk(), false, CalendarKind::Heap));
    assert_eq!(want, heap_coalesced, "{label}: coalesced egress diverged on the heap calendar");
}

/// Near-capacity single-replica colocated world: decode batches stay full,
/// so every iteration emits a multi-token egress burst.
fn busy_colocated() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(400);
    cfg.window = SimDur::from_ms(5);
    cfg.warmup_windows = 5;
    cfg.calib_windows = 20;
    cfg.workload.arrival = Arrival::Poisson { rate: 2_000.0 };
    cfg.workload.prompt_len = LengthDist::Uniform { lo: 8, hi: 16 };
    cfg.workload.output_len = LengthDist::Uniform { lo: 4, hi: 16 };
    cfg
}

/// Four colocated replicas at fleet scale: concurrent egress lanes whose
/// batch events interleave with each other and with every replica's
/// iteration events.
fn busy_fleet() -> ScenarioCfg {
    let mut cfg = fleet_base_cfg(4);
    cfg.duration = SimDur::from_ms(400);
    cfg.window = SimDur::from_ms(5);
    cfg.warmup_windows = 5;
    cfg.calib_windows = 20;
    cfg.workload.arrival = Arrival::Poisson { rate: 3_000.0 };
    cfg
}

/// The disaggregation topology: prefill-pool replicas emit their first
/// token through the same egress path before the KV handoff, so the
/// coalesced lane must also replay the cross-pool case exactly.
fn busy_disagg() -> ScenarioCfg {
    let mut cfg = disagg_base_cfg();
    cfg.duration = SimDur::from_ms(500);
    cfg
}

#[test]
fn coalesced_egress_is_byte_identical_on_a_colocated_replica() {
    assert_equivalent(busy_colocated, "colocated");
}

#[test]
fn coalesced_egress_is_byte_identical_across_a_fleet() {
    assert_equivalent(busy_fleet, "fleet");
}

#[test]
fn coalesced_egress_is_byte_identical_through_the_disagg_handoff() {
    assert_equivalent(busy_disagg, "disagg");
}
