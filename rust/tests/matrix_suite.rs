//! E2E coverage for `coordinator::matrix` — the parallel detection-quality
//! scorecard subsystem (paper §§4.1-4.3, Tables 3a-c as data):
//!
//! * the fast config (one replicate of the standard shaped scenarios, the
//!   exact configuration the serial E5 bench ran) identifies all 28 runbook
//!   conditions, with zero EW1 firings in the §4.3 NVLink negative control;
//! * the scorecard JSON is byte-identical across repeated runs and across
//!   worker-thread counts (the `BENCH_*.json` trajectory contract).

use dpulens::coordinator::experiment::standard_cfg;
use dpulens::coordinator::matrix::{run_matrix, MatrixConfig};
use dpulens::sim::SimDur;

#[test]
fn fast_matrix_identifies_all_28_conditions() {
    let report = run_matrix(&MatrixConfig::fast());

    assert_eq!(report.scorecards.len(), 28);
    for s in &report.scorecards {
        assert_eq!(s.runs, 1, "{} unexpected run count", s.condition.id());
        assert!(
            s.identified(),
            "{} not detected on the fast config (self_firings={}, other_firings={})",
            s.condition.id(),
            s.self_firings,
            s.other_firings
        );
        assert!(s.self_firings >= 1, "{} diagonal empty", s.condition.id());
        assert!(
            !s.latency_ns.is_empty(),
            "{} detected but no time-to-detect sample",
            s.condition.id()
        );
        assert!(
            s.sw_identified_runs <= s.sw_noticed_runs,
            "{} SW identified without noticing",
            s.condition.id()
        );
    }
    assert_eq!(report.detected_count(), 28, "diagonal not dominant");
    assert!((report.macro_recall() - 1.0).abs() < 1e-12);

    // Healthy false-alarm floor was measured.
    assert!(report.healthy_runs >= 1);
    assert!(report.healthy_windows > 0);

    // §4.3: with TP pinned to NVLink the straggler must stay invisible.
    let nc = report.negative_control.as_ref().expect("negative control ran");
    assert!(nc.runs >= 1);
    assert_eq!(nc.ew1_detections, 0, "EW1 fired despite NVLink blindness");
    assert!(nc.invisible_dropped > 0, "visibility boundary rejected nothing");

    // The machine-readable form round-trips the headline numbers.
    let json = report.to_json().render();
    assert!(json.contains("\"schema\":\"dpulens.matrix.v1\""));
    assert!(json.contains("\"detected\":28"));
    assert!(json.contains("\"ew1_detections\":0"));
}

#[test]
fn matrix_scorecard_json_is_deterministic() {
    // Trimmed scenario so this stays cheap: detection success is irrelevant
    // here, only bit-stable aggregation and serialization.
    let mut base = standard_cfg();
    base.duration = SimDur::from_ms(1300);
    base.warmup_windows = 10;
    base.calib_windows = 50;

    let mk = |threads: usize| MatrixConfig {
        base: base.clone(),
        replicates: 1,
        threads,
        negative_control: true,
        no_reuse: false,
    };

    let a = run_matrix(&mk(2)).to_json().render();
    let b = run_matrix(&mk(3)).to_json().render();
    assert_eq!(a, b, "scorecard JSON differs across runs/thread counts");
    assert!(a.contains("\"replicates\":1"));
}
