//! Zero-copy pipeline assertions (requires `--features perf-probe`).
//!
//! The batched telemetry path — scenario outbox → bus per-node buffers →
//! DPU agent slices — must never clone a `TelemetryEvent` unless a recorder
//! ring is attached. The probe counters are thread-local, and the scenario
//! below runs entirely on this test's thread, so concurrent tests cannot
//! perturb the count.

use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::sim::SimDur;
use dpulens::util::perf::probe;

fn quick_cfg() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(600);
    cfg.window = SimDur::from_ms(10);
    cfg.warmup_windows = 5;
    cfg.calib_windows = 20;
    cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 300.0 };
    cfg.workload.prompt_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 32 };
    cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 2, hi: 8 };
    cfg
}

#[test]
fn non_recorder_path_clones_zero_telemetry_events() {
    probe::reset();
    let res = Scenario::new(quick_cfg()).run();
    assert!(res.telemetry_published > 1_000, "run too small to be meaningful");
    assert_eq!(
        probe::event_clones(),
        0,
        "the batched bus -> agent pipeline cloned telemetry events"
    );
}

#[test]
fn recorder_is_the_only_clone_site() {
    use dpulens::ids::{GpuId, NodeId};
    use dpulens::sim::SimTime;
    use dpulens::telemetry::event::{TelemetryEvent, TelemetryKind};
    use dpulens::telemetry::TelemetryBus;

    probe::reset();
    let mut bus = TelemetryBus::new(1).with_recorder(16);
    for i in 0..10u64 {
        bus.emit(SimTime(i), NodeId(0), TelemetryKind::Doorbell { gpu: GpuId(0) });
    }
    // One clone per recorded event, none from delivery.
    assert_eq!(probe::event_clones(), 10);
    let before = probe::event_clones();
    bus.deliver_due(SimTime(100), |_, evs| {
        std::hint::black_box(evs);
    });
    assert_eq!(probe::event_clones(), before, "delivery cloned events");

    // Sanity: the probe does count an explicit clone.
    let ev = TelemetryEvent {
        t: SimTime(0),
        node: NodeId(0),
        kind: TelemetryKind::Doorbell { gpu: GpuId(0) },
    };
    let _c = ev.clone();
    assert_eq!(probe::event_clones(), before + 1);
}
