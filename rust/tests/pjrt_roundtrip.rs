//! Integration: the AOT bridge end to end — python-lowered HLO artifacts
//! executed from Rust must reproduce python's own numbers (golden.txt).
//!
//! Requires `make artifacts`. These tests are the cross-language correctness
//! anchor for the whole L1/L2 <-> L3 interface.

use dpulens::dpu::scorer::{NativeScorer, ScorerBackend};
use dpulens::runtime::{cpu_client, ArtifactSet, CompiledScorer, TransformerSession};

fn artifacts() -> Option<ArtifactSet> {
    match ArtifactSet::open_default() {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

/// Rebuild the deterministic golden inputs (mirrors aot.golden_inputs).
fn golden_inputs(m: &dpulens::runtime::Manifest) -> (Vec<Vec<i32>>, Vec<i32>) {
    let tokens: Vec<Vec<i32>> = (0..m.batch)
        .map(|i| {
            (0..m.prefill_len)
                .map(|j| ((7 * i + 11 * j + 3) % m.vocab) as i32)
                .collect()
        })
        .collect();
    let lens: Vec<i32> = (0..m.batch)
        .map(|i| {
            let v = (m.prefill_len / 2 + 5 * i + 1) % m.prefill_len + 1;
            v.max(1) as i32
        })
        .collect();
    (tokens, lens)
}

#[test]
fn prefill_and_decode_match_python_goldens() {
    let Some(arts) = artifacts() else { return };
    let client = cpu_client().expect("PJRT CPU client");
    let mut session = TransformerSession::load(&client, &arts).expect("load artifacts");
    let (golden_prefill, golden_tokens, golden_decode) =
        arts.load_golden().expect("golden.txt");

    let (tokens, lens) = golden_inputs(&arts.manifest);
    let logits = session.prefill_block(&tokens, &lens).expect("prefill");

    // Prefill logits match python to float tolerance.
    for b in 0..arts.manifest.batch {
        for j in 0..8 {
            let got = logits[b][j];
            let want = golden_prefill[b][j];
            assert!(
                (got - want).abs() < 2e-3 + 1e-3 * want.abs(),
                "prefill logit[{b}][{j}]: rust {got} vs python {want}"
            );
        }
    }

    // Greedy decode: token-for-token agreement over the golden steps.
    let mut cur: Vec<i32> = logits.iter().map(|l| TransformerSession::argmax(l)).collect();
    let mut positions: Vec<i32> = lens.clone();
    for (t, golden_step) in golden_tokens.iter().enumerate() {
        assert_eq!(&cur, golden_step, "greedy tokens diverged at step {t}");
        let logits = session.decode_step(&cur, &positions).expect("decode");
        for b in 0..arts.manifest.batch {
            for j in 0..8 {
                let got = logits[b][j];
                let want = golden_decode[t][b][j];
                assert!(
                    (got - want).abs() < 5e-3 + 2e-3 * want.abs(),
                    "decode logit step {t} [{b}][{j}]: rust {got} vs python {want}"
                );
            }
        }
        cur = logits.iter().map(|l| TransformerSession::argmax(l)).collect();
        for p in &mut positions {
            *p += 1;
        }
    }
    assert!(session.decode_calls >= golden_tokens.len() as u64);
}

#[test]
fn slot_surgery_preserves_other_sequences() {
    // Prefill slots {0,1}, decode once, then prefill slot 1 with a NEW
    // prompt: slot 0's next decode must be unaffected (KV splice works).
    let Some(arts) = artifacts() else { return };
    let client = cpu_client().expect("client");
    let m = &arts.manifest;
    let (tokens, _) = golden_inputs(m);
    let prompt0: Vec<i32> = tokens[0][..16].to_vec();
    let prompt1: Vec<i32> = tokens[1][..20].to_vec();
    let prompt_new: Vec<i32> = tokens[2][..12].to_vec();

    use dpulens::engine::exec::ComputeBackend;
    // Reference run: only slot 0 live the whole time.
    let mut a = TransformerSession::load(&client, &arts).expect("load");
    let t0 = a.prefill(&[0], &[prompt0.as_slice()])[0];
    let a1 = a.decode(&[0], &[t0], &[16])[0];
    let a2 = a.decode(&[0], &[a1], &[17])[0];

    // Test run: slot 1 gets prefilled mid-stream; slot 0 must not notice.
    let mut b = TransformerSession::load(&client, &arts).expect("load");
    let u0 = b.prefill(&[0, 1], &[prompt0.as_slice(), prompt1.as_slice()])[0];
    assert_eq!(t0, u0, "same prompt, same first token");
    let b1 = b.decode(&[0], &[u0], &[16])[0];
    assert_eq!(a1, b1);
    let _ = b.prefill(&[1], &[prompt_new.as_slice()]); // slot-1 replacement
    let b2 = b.decode(&[0], &[b1], &[17])[0];
    assert_eq!(a2, b2, "slot-1 prefill corrupted slot 0's KV");
}

#[test]
fn compiled_scorer_matches_native_and_python_contract() {
    let Some(arts) = artifacts() else { return };
    let client = cpu_client().expect("client");
    let mut compiled = CompiledScorer::load(&client, &arts).expect("scorer");
    let mut native = NativeScorer;

    let w = arts.manifest.detector_windows;
    let n = arts.manifest.detector_samples;
    let windows: Vec<Vec<f32>> = (0..w)
        .map(|i| (0..n).map(|j| ((i * 31 + j * 7) % 113) as f32 * 0.5).collect())
        .collect();
    let baseline: Vec<(f32, f32)> = (0..w).map(|i| (20.0 + i as f32, 9.0)).collect();

    let (fn_, zn) = native.score(&windows, &baseline);
    let (fc, zc) = compiled.score(&windows, &baseline);
    assert_eq!(fn_.len(), fc.len());
    for (i, (a, b)) in fn_.iter().zip(&fc).enumerate() {
        for k in 0..8 {
            assert!(
                (a[k] - b[k]).abs() < 1e-2 + 1e-3 * a[k].abs(),
                "feature[{i}][{k}]: native {} vs compiled {}",
                a[k],
                b[k]
            );
        }
    }
    for (a, b) in zn.iter().zip(&zc) {
        assert!((a - b).abs() < 1e-3 + 1e-3 * a.abs());
    }
}

#[test]
fn manifest_matches_rust_profile() {
    let Some(arts) = artifacts() else { return };
    let m = &arts.manifest;
    let p = dpulens::engine::preset(&m.preset).expect("preset known to rust");
    assert_eq!(p.layers, m.layers);
    assert_eq!(p.d_model, m.d_model);
    assert_eq!(p.vocab, m.vocab);
    assert_eq!(p.max_seq, m.max_seq);
    assert_eq!(p.prefill_len, m.prefill_len);
    assert_eq!(p.batch, m.batch);
}
