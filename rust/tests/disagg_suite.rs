//! Acceptance coverage for the phase-disaggregated serving plane:
//!
//! * a healthy 2-pool world serves end to end, with every request crossing
//!   the prefill→decode boundary through a conserved KV handoff;
//! * `dpulens fleet --disagg` detects all of PD1-PD3 on the 2-pool topology
//!   and the post-`RebalancePools` (and sibling PD directives) runs recover
//!   ≥ 80% of healthy decode throughput;
//! * with disaggregation off, the fleet JSON stays schema v1 with no disagg
//!   section; with it on, the v2 JSON is byte-identical across thread
//!   counts.

use dpulens::coordinator::fleet::{disagg_base_cfg, run_disagg_study, run_fleet, FleetConfig};
use dpulens::coordinator::Scenario;
use dpulens::dpu::detectors::{Condition, PD_CONDITIONS};
use dpulens::sim::SimDur;

#[test]
fn healthy_two_pool_world_serves_through_the_handoff() {
    let mut cfg = disagg_base_cfg();
    cfg.duration = SimDur::from_ms(1500);
    cfg.warmup_windows = 10;
    cfg.calib_windows = 40;
    let res = Scenario::new(cfg).run();

    assert!(res.metrics.completed > 100, "completed {}", res.metrics.completed);
    // Every multi-token request crossed the pool boundary exactly once.
    assert!(res.handoffs.started > 100, "handoffs {}", res.handoffs.started);
    assert!(res.handoffs.completed <= res.handoffs.started);
    // Conservation: every landed handoff delivered its exact byte count;
    // the sent/delivered gap is precisely the in-flight tail.
    assert!(res.handoffs.bytes_delivered <= res.handoffs.bytes_sent);
    assert!(
        res.handoffs_inflight_at_end() < 50,
        "handoff backlog at end: {}",
        res.handoffs_inflight_at_end()
    );
    // Decode work lands on the decode pool: the prefill replica (lane 0)
    // retains only what it finished at prefill, the decode lanes the rest.
    let decode_tokens: u64 =
        res.metrics.per_replica[1].tokens_out + res.metrics.per_replica[2].tokens_out;
    assert!(
        decode_tokens > res.metrics.per_replica[0].tokens_out,
        "decode pool served {:?}",
        res.metrics.per_replica
    );
    // Both decode replicas participate under load-balanced handoff routing.
    assert!(res.handoffs.arrivals_per_replica[1] > 0);
    assert!(res.handoffs.arrivals_per_replica[2] > 0);
    // A healthy disaggregated world raises no PD alarms.
    for c in PD_CONDITIONS {
        assert!(!res.detected(c), "{} fired on a healthy 2-pool world", c.id());
    }
}

#[test]
fn pd_family_detected_and_mitigated_on_the_two_pool_topology() {
    let report = run_disagg_study(0);

    assert_eq!(report.pd_rows.len(), PD_CONDITIONS.len());
    assert!(report.handoffs > 0, "healthy disagg cell shipped no KV handoffs");
    assert!(report.disagg_tok_per_s > 0.0 && report.colocated_tok_per_s > 0.0);

    for row in &report.pd_rows {
        assert!(row.detected, "{} not detected on the 2-pool topology", row.condition.id());
        assert!(
            row.latency_ns.is_some(),
            "{} detected but no time-to-detect sample",
            row.condition.id()
        );
        assert!(
            row.actions >= 1,
            "{} fired but the controller took no action",
            row.condition.id()
        );
        assert!(row.injected_tok_per_s > 0.0, "{} served nothing", row.condition.id());
        // The acceptance bar: the mitigated run recovers at least 80% of
        // the healthy (same-shaped, uninjected) decode throughput.
        assert!(
            row.mitigated_tok_per_s >= 0.8 * row.healthy_tok_per_s,
            "{}: mitigated {:.0} tok/s < 80% of healthy {:.0} tok/s",
            row.condition.id(),
            row.mitigated_tok_per_s,
            row.healthy_tok_per_s
        );
    }

    // PD3's wedge must visibly cost throughput (one decode replica cannot
    // carry the slot-saturating load), and mitigation must win it back.
    let pd3 = report
        .pd_rows
        .iter()
        .find(|r| r.condition == Condition::Pd3DecodeStarvation)
        .unwrap();
    assert!(
        pd3.injected_tok_per_s < 0.95 * pd3.healthy_tok_per_s,
        "PD3 injection did not dent throughput: {:.0} vs healthy {:.0}",
        pd3.injected_tok_per_s,
        pd3.healthy_tok_per_s
    );
    assert!(
        pd3.mitigated_tok_per_s > pd3.injected_tok_per_s,
        "PD3 mitigation did not recover over injected"
    );
}

#[test]
fn fleet_json_stays_v1_without_disagg_and_v2_is_thread_stable() {
    // Off by default: schema v1, no disagg section.
    let mut base = dpulens::coordinator::fleet::fleet_base_cfg(2);
    base.duration = SimDur::from_ms(1200);
    base.warmup_windows = 10;
    base.calib_windows = 40;
    let mk = |threads: usize, disagg: bool| FleetConfig {
        base: base.clone(),
        replicas: 2,
        policies: vec![dpulens::engine::RoutePolicy::FlowHash],
        threads,
        disagg,
        multipool: None,
        telemetry_faults: false,
        no_reuse: false,
    };
    let v1 = run_fleet(&mk(2, false)).to_json().render();
    assert!(v1.contains("\"schema\":\"dpulens.fleet.v1\""));
    assert!(!v1.contains("\"disagg\""));

    // The disagg section itself is deterministic across thread counts.
    let a = run_disagg_study(2).to_json().render();
    let b = run_disagg_study(3).to_json().render();
    assert_eq!(a, b, "disagg JSON differs across thread counts");
    assert!(a.contains("\"pd_conditions\""));
    assert!(a.contains("\"prefill:tp8xpp1\""));
}
