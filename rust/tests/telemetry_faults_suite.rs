//! Acceptance coverage for the degraded-telemetry plane:
//!
//! * each TD condition (frozen exporter, lossy path, lagging delivery) is
//!   detected from the DPU vantage on a telemetry-weighted fleet, and the
//!   widened conservation identity (`published == ingested + invisible +
//!   fault_dropped + fault_held`) holds exactly;
//! * the router's fallback ladder traverses all three levels under an
//!   unmitigated freeze and walks back to full telemetry — one level per
//!   hysteresis streak — once mitigation repairs the path;
//! * a healthy run never touches the fault plane: no ladder transitions, no
//!   fault counters, no TD alarms, pristine conservation;
//! * `run_telemetry_study` (the `dpulens fleet --telemetry-faults` section)
//!   detects all of TD1-TD3 and the v4 fleet JSON is byte-identical across
//!   thread counts.

use dpulens::coordinator::experiment::inject_time;
use dpulens::coordinator::fleet::{fleet_base_cfg, run_fleet, run_telemetry_study, FleetConfig};
use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::dpu::detectors::{Condition, TD_CONDITIONS};
use dpulens::dpu::watchdog::{FreshnessWatchdog, RECOVERY_STREAK};
use dpulens::engine::RoutePolicy;
use dpulens::sim::SimDur;
use dpulens::telemetry::FreshnessStat;

/// A trimmed 2-replica fleet on the telemetry-weighted baseline — the
/// routing policy whose picks actually consume the gauges the faults rot,
/// so the fallback ladder has something to protect.
fn td_cfg() -> ScenarioCfg {
    let mut cfg = fleet_base_cfg(2);
    cfg.engine.route_policy = RoutePolicy::WeightedTelemetry;
    cfg.duration = SimDur::from_ms(2000);
    cfg.warmup_windows = 10;
    cfg.calib_windows = 40;
    cfg
}

#[test]
fn td_family_detected_with_widened_conservation() {
    for c in TD_CONDITIONS {
        let mut cfg = td_cfg();
        cfg.inject = Some((c, inject_time(&cfg)));
        let res = Scenario::new(cfg).run();

        assert!(res.detected(c), "{} not detected on the weighted fleet", c.id());
        assert!(
            res.detection_latency(c).is_some(),
            "{} detected but no time-to-detect sample",
            c.id()
        );
        // Every event the cluster published is accounted for: delivered,
        // invisibly dropped pre-DPU, discarded at the fault boundary, or
        // still parked in a lag hold queue at run end.
        assert_eq!(
            res.telemetry_published,
            res.dpu_ingested + res.dpu_invisible_dropped + res.fault_dropped + res.fault_held_at_end,
            "{}: widened conservation identity broken",
            c.id()
        );
        assert!(
            !res.ladder_transitions.is_empty(),
            "{} degraded the feed but the ladder never moved",
            c.id()
        );
        match c {
            // Freeze and lossy-drop discard events at the boundary.
            Condition::Td1StaleFrozen | Condition::Td2LossyDrop => {
                assert!(res.fault_dropped > 0, "{} dropped nothing", c.id());
            }
            // Lag loses nothing — it parks, so the run ends with a backlog.
            _ => {
                assert_eq!(res.fault_dropped, 0, "TD3 must not drop");
                assert!(res.fault_held_at_end > 0, "TD3 ended with no held backlog");
            }
        }
    }
}

#[test]
fn fallback_ladder_traverses_three_levels_and_recovers_with_hysteresis() {
    // Unmitigated freeze: the victim's signal age grows without bound, so
    // the watchdog must walk the full ladder — weighted, KV-blind,
    // least-loaded, round-robin — and never come back.
    let mut cfg = td_cfg();
    cfg.inject = Some((Condition::Td1StaleFrozen, inject_time(&cfg)));
    let res = Scenario::new(cfg).run();
    let levels: Vec<u8> = res.ladder_transitions.iter().map(|&(_, l)| l).collect();
    for lvl in [1u8, 2, 3] {
        assert!(levels.contains(&lvl), "ladder skipped level {lvl}: {levels:?}");
    }
    assert!(
        levels.windows(2).all(|w| w[1] > w[0]),
        "unmitigated freeze may only descend deeper into fallback: {levels:?}"
    );

    // Mitigated freeze: the closed loop restarts the exporter, freshness
    // returns, and the ladder steps back one level per hysteresis streak.
    let mut cfg = td_cfg();
    cfg.inject = Some((Condition::Td1StaleFrozen, inject_time(&cfg)));
    cfg.mitigate = true;
    let res = Scenario::new(cfg).run();
    let t = &res.ladder_transitions;
    assert!(!t.is_empty(), "mitigated run recorded no ladder transitions");
    assert_eq!(t.last().unwrap().1, 0, "ladder did not recover to full telemetry: {t:?}");
    let peak = t.iter().enumerate().max_by_key(|&(_, &(_, l))| l).map(|(i, _)| i).unwrap();
    assert!(t[peak].1 >= 1, "mitigated run never degraded: {t:?}");
    for pair in t[peak..].windows(2) {
        let (w0, l0) = pair[0];
        let (w1, l1) = pair[1];
        assert_eq!(l1 + 1, l0, "recovery must step down one level at a time: {t:?}");
        assert!(
            w1 - w0 >= u64::from(RECOVERY_STREAK),
            "stepped down after only {} calm windows: {t:?}",
            w1 - w0
        );
    }
}

#[test]
fn healthy_runs_never_touch_the_fault_plane() {
    let res = Scenario::new(td_cfg()).run();
    assert!(
        res.ladder_transitions.is_empty(),
        "ladder moved on a healthy run: {:?}",
        res.ladder_transitions
    );
    assert_eq!(res.fault_dropped, 0);
    assert_eq!(res.fault_held_at_end, 0);
    for c in TD_CONDITIONS {
        assert!(!res.detected(c), "{} fired on a healthy fleet", c.id());
    }
    // With the fault counters at zero the widened identity collapses back
    // to the pristine pipeline's exact conservation.
    assert_eq!(res.telemetry_published, res.dpu_ingested + res.dpu_invisible_dropped);
}

/// The watchdog's public surface, driven from outside the crate the way the
/// observe loop drives it: degrade-fast to the raw assessment, recover-slow
/// one level per full calm streak, relapse resets the streak.
#[test]
fn watchdog_hysteresis_over_the_public_api() {
    let fresh = FreshnessStat { emitted: 100, delivered: 100, ..Default::default() };

    // Monotone: a signal that only gets older never lowers the level.
    let mut wd = FreshnessWatchdog::new();
    let mut prev = 0u8;
    for age in 0..30u64 {
        let lvl = wd.window_tick(&[FreshnessStat { age_windows: age, ..fresh }]);
        assert!(lvl >= prev, "level dropped {prev} -> {lvl} while freshness only worsened");
        prev = lvl;
    }
    assert_eq!(prev, 3, "unbounded staleness must reach round-robin");

    // Hysteresis: one bad window jumps straight to 3; each step back down
    // costs a full calm streak, and a relapse jumps right back up.
    let mut wd = FreshnessWatchdog::new();
    assert_eq!(wd.window_tick(&[FreshnessStat { age_windows: 20, ..fresh }]), 3);
    for i in 1..RECOVERY_STREAK {
        assert_eq!(wd.window_tick(&[fresh]), 3, "recovered after only {i} calm windows");
    }
    assert_eq!(wd.window_tick(&[fresh]), 2, "full streak must step down exactly one level");
    for _ in 0..RECOVERY_STREAK - 1 {
        wd.window_tick(&[fresh]);
    }
    wd.window_tick(&[FreshnessStat { age_windows: 20, ..fresh }]);
    assert_eq!(wd.level(), 3, "a relapse must jump back up immediately");
}

#[test]
fn telemetry_study_detects_all_td_conditions_and_recovers_the_ladder() {
    let report = run_telemetry_study(0);

    assert_eq!(report.rows.len(), TD_CONDITIONS.len());
    for (row, &c) in report.rows.iter().zip(TD_CONDITIONS.iter()) {
        assert_eq!(row.condition, c, "study rows out of catalog order");
        assert!(row.detected, "{} not detected in the telemetry study", c.id());
        assert!(row.latency_ns.is_some(), "{} has no time-to-detect sample", c.id());
        assert!(row.actions >= 1, "{} fired but the controller took no action", c.id());
        assert!(
            !row.ladder_transitions.is_empty(),
            "{} never moved the fallback ladder",
            c.id()
        );
        assert_eq!(
            row.recovered_level, 0,
            "{} mitigated cell did not walk the ladder back to full telemetry",
            c.id()
        );
        // The ladder's whole point: routing on degraded (or no) telemetry
        // must not collapse serving throughput.
        assert!(
            row.throughput_held >= 0.7,
            "{}: ladder held only {:.0}% of healthy throughput",
            c.id(),
            row.throughput_held * 100.0
        );
    }

    // The frozen exporter is the only signature whose staleness grows
    // without bound: it must bottom out at round-robin and lose events.
    let td1 = &report.rows[0];
    assert_eq!(
        td1.max_ladder_level, 3,
        "frozen telemetry must walk the full ladder: {:?}",
        td1.ladder_transitions
    );
    assert!(td1.fault_dropped > 0, "TD1 discarded nothing at the boundary");
}

#[test]
fn fleet_json_bumps_to_v4_only_with_telemetry_faults() {
    let mut base = fleet_base_cfg(2);
    base.duration = SimDur::from_ms(1200);
    base.warmup_windows = 10;
    base.calib_windows = 40;
    let mk = |threads: usize, telemetry_faults: bool| FleetConfig {
        base: base.clone(),
        replicas: 2,
        policies: vec![RoutePolicy::WeightedTelemetry],
        threads,
        disagg: false,
        multipool: None,
        telemetry_faults,
        no_reuse: false,
    };

    let off = run_fleet(&mk(2, false)).to_json().render();
    assert!(off.contains("\"schema\":\"dpulens.fleet.v1\""));
    assert!(!off.contains("\"telemetry\""));

    let a = run_fleet(&mk(2, true)).to_json().render();
    let b = run_fleet(&mk(3, true)).to_json().render();
    assert_eq!(a, b, "fleet v4 JSON differs across thread counts");
    assert!(a.contains("\"schema\":\"dpulens.fleet.v4\""));
    assert!(a.contains("\"td_conditions\""));
    assert!(a.contains("\"max_ladder_level\""));

    // The TD block rides at the end of the cell list: everything before the
    // DP section renders byte-identically with the study on and off.
    let prefix_off = off.split("\"dp_conditions\"").next().unwrap().replace(".v1", "");
    let prefix_on = a.split("\"dp_conditions\"").next().unwrap().replace(".v4", "");
    assert_eq!(prefix_off, prefix_on, "enabling the TD study perturbed the v1 cells");
}
