//! Acceptance coverage for the multi-pool serving plane (ISSUE 5):
//!
//! * `dpulens fleet --disagg --replicas 6 --prefill-pools 2` territory: a
//!   6-replica topology with 2 admission pools and 1 handoff pool serves
//!   end to end, with pool-confined routing and per-pool-pair handoff
//!   accounting;
//! * the catalog-driven condition study detects at least one DP and one PD
//!   condition on the multi-pool topology, and those detections recover
//!   ≥ 80% of the same-shaped healthy throughput post-mitigation;
//! * the v3 multipool JSON section is byte-identical across worker-thread
//!   counts (the v1/v2 stability suites live in fleet_suite/disagg_suite).

use dpulens::coordinator::fleet::{
    multipool_base_cfg, run_multipool_study, MultiPoolSpec,
};
use dpulens::coordinator::Scenario;
use dpulens::sim::SimDur;

fn spec() -> MultiPoolSpec {
    MultiPoolSpec { replicas: 6, prefill_pools: 2, decode_pools: 1 }
}

#[test]
fn healthy_multipool_world_serves_through_pooled_routing() {
    let mut cfg = multipool_base_cfg(&spec());
    cfg.duration = SimDur::from_ms(1500);
    cfg.warmup_windows = 10;
    cfg.calib_windows = 40;
    let res = Scenario::new(cfg).run();

    assert!(res.metrics.completed > 100, "completed {}", res.metrics.completed);
    // Both admission pools see traffic (flows hash across pools)...
    assert!(res.replica_routed[0] > 0, "{:?}", res.replica_routed);
    assert!(res.replica_routed[1] > 0, "{:?}", res.replica_routed);
    // ...and only prefill replicas take admissions.
    assert!(res.replica_routed[2..].iter().all(|&n| n == 0), "{:?}", res.replica_routed);
    // Handoffs flow, and every launch is attributed to a pool pair.
    assert!(res.handoffs.started > 100, "handoffs {}", res.handoffs.started);
    let pair_total: u64 = res.handoffs.per_pair.iter().map(|p| p.started).sum();
    assert_eq!(pair_total, res.handoffs.started, "pool-pair accounting must conserve");
    let pair_bytes: u64 = res.handoffs.per_pair.iter().map(|p| p.bytes_sent).sum();
    assert_eq!(pair_bytes, res.handoffs.bytes_sent);
    // Both prefill pools hand off into the (single) decode pool.
    for p in 0..2u32 {
        let from_p: u64 = res
            .handoffs
            .per_pair
            .iter()
            .filter(|e| e.prefill_pool == p)
            .map(|e| e.started)
            .sum();
        assert!(from_p > 0, "prefill pool {p} shipped no handoffs: {:?}", res.handoffs.per_pair);
    }
    // Every decode replica participates under load-balanced handoffs.
    for r in 2..6 {
        assert!(
            res.handoffs.arrivals_per_replica[r] > 0,
            "decode replica {r} starved: {:?}",
            res.handoffs.arrivals_per_replica
        );
    }
}

#[test]
fn multipool_study_detects_and_recovers_dp_and_pd_conditions() {
    let report = run_multipool_study(spec(), 0);

    assert_eq!(report.replicas, 6);
    assert_eq!(report.prefill_pool_count, 2);
    assert_eq!(report.decode_pool_count, 1);
    assert_eq!(report.prefill_pools, vec![vec![0], vec![1]]);
    assert_eq!(report.decode_pools, vec![vec![2, 3, 4, 5]]);
    // DP1's peer-skew rule is structurally inert on singleton prefill
    // pools: reported as skipped, not run as a guaranteed-negative triple.
    assert_eq!(
        report.skipped,
        vec![dpulens::dpu::detectors::Condition::Dp1RouterFlowSkew]
    );
    assert_eq!(report.rows.len(), 5, "one row per applicable fleet condition");
    assert!(report.handoffs > 0, "healthy multipool cell shipped no KV handoffs");

    // The acceptance bar (ISSUE 5): at least one DP and one PD condition is
    // detected on the multi-pool topology, with its mitigated run back at
    // ≥ 80% of the same-shaped healthy throughput.
    let recovered = |r: &dpulens::coordinator::fleet::DpRow| {
        r.detected && r.mitigated_tok_per_s >= 0.8 * r.healthy_tok_per_s
    };
    let dp_ok: Vec<&str> = report
        .rows
        .iter()
        .filter(|r| r.condition.table() == "dp" && recovered(r))
        .map(|r| r.condition.id())
        .collect();
    let pd_ok: Vec<&str> = report
        .rows
        .iter()
        .filter(|r| r.condition.table() == "pd" && recovered(r))
        .map(|r| r.condition.id())
        .collect();
    let summary: Vec<String> = report
        .rows
        .iter()
        .map(|r| {
            format!(
                "{}: detected={} healthy={:.0} injected={:.0} mitigated={:.0}",
                r.condition.id(),
                r.detected,
                r.healthy_tok_per_s,
                r.injected_tok_per_s,
                r.mitigated_tok_per_s
            )
        })
        .collect();
    assert!(!dp_ok.is_empty(), "no DP condition detected+recovered: {summary:?}");
    assert!(!pd_ok.is_empty(), "no PD condition detected+recovered: {summary:?}");
    // Detected rows carry a time-to-detect sample and controller actions.
    for r in report.rows.iter().filter(|r| r.detected) {
        assert!(r.latency_ns.is_some(), "{} detected without latency", r.condition.id());
    }
}

#[test]
fn multipool_json_is_thread_stable() {
    // A smaller 4-replica / 2-pool topology keeps the double run cheap;
    // determinism is what's under test, not detection.
    let small = MultiPoolSpec { replicas: 4, prefill_pools: 2, decode_pools: 1 };
    let a = run_multipool_study(small, 2).to_json().render();
    let b = run_multipool_study(small, 3).to_json().render();
    assert_eq!(a, b, "multipool JSON differs across thread counts");
    assert!(a.contains("\"prefill_pool_count\":2"));
    assert!(a.contains("\"handoff_pairs\""));
    assert!(a.contains("\"conditions\""));
    assert!(a.contains("\"skipped\":[\"DP1\"]"));
    assert!(a.contains("\"prefill:tp4xpp1\""));
}
