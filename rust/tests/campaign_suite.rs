//! Arrival-path property suite for the campaign-era workload plane:
//!
//! * generation-clock monotonicity when thin sessions jitter deliveries
//!   (the bug-2 regression surface: a late-delivered request must not stall
//!   or reorder the generation stream behind it),
//! * request conservation across *every* workload-site injection (the bug-1
//!   regression surface: a mid-run generator swap must not reissue live
//!   ReqIds and orphan engine bookkeeping), and
//! * byte-stability of the campaign JSON across thread counts (the
//!   dpulens.campaign.v1 determinism contract).

use dpulens::conditions::{all_specs, InjectSite};
use dpulens::coordinator::campaign::{run_campaign, CampaignConfig};
use dpulens::coordinator::experiment::{inject_time, standard_cfg};
use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::sim::dist::{Arrival, LengthDist, RateShape};
use dpulens::sim::SimDur;
use dpulens::workload::generator::{WorkloadGen, WorkloadSpec};

/// A short scenario with enough headroom past the standard injection
/// instant (800ms here) to exercise post-injection generation.
fn quick_cfg() -> ScenarioCfg {
    let mut cfg = standard_cfg();
    cfg.duration = SimDur::from_ms(1100);
    cfg.warmup_windows = 10;
    cfg.calib_windows = 40;
    cfg.workload.arrival = Arrival::Poisson { rate: 300.0 };
    cfg.workload.prompt_len = LengthDist::Uniform { lo: 8, hi: 32 };
    cfg.workload.output_len = LengthDist::Uniform { lo: 2, hi: 8 };
    cfg
}

#[test]
fn generation_clock_is_monotone_under_thin_sessions() {
    let spec = WorkloadSpec {
        arrival: Arrival::Poisson { rate: 400.0 },
        rate_shape: RateShape::compose(
            RateShape::Diurnal { period_s: 2.0, min_factor: 0.6 },
            RateShape::FlashCrowd { at_s: 0.4, surge: 3.0, decay_s: 0.2 },
        ),
        session_skew: 1.4,
        thin_session_frac: 0.3,
        thin_extra_gap_s: 0.2,
        ..WorkloadSpec::default()
    };
    let mut g = WorkloadGen::new(spec, 32_000, 9);
    let mut prev_clock = g.clock();
    let mut jittered = 0usize;
    for _ in 0..800 {
        let r = g.next_request();
        let clock = g.clock();
        // The undelayed generation clock never goes backwards: a thin
        // session's delivery jitter is per-request, not a stream stall.
        assert!(clock >= prev_clock, "generation clock regressed");
        // Every request is delivered at or after the instant it was
        // generated (the jitter only ever delays).
        assert!(r.arrival >= clock, "arrival {:?} precedes generation {clock:?}", r.arrival);
        if r.arrival > clock {
            jittered += 1;
        }
        prev_clock = clock;
    }
    assert!(jittered > 50, "thin sessions produced only {jittered} jittered deliveries");
}

#[test]
fn requests_are_conserved_across_every_workload_site_injection() {
    let conds: Vec<_> =
        all_specs().filter(|s| s.site == InjectSite::Workload).map(|s| s.condition).collect();
    assert!(conds.len() >= 5, "workload-site condition family shrank: {conds:?}");
    for c in conds {
        let mut cfg = quick_cfg();
        cfg.inject = Some((c, inject_time(&cfg)));
        let res = Scenario::new(cfg).run();
        assert!(res.injected_at.is_some(), "{}: injection never landed", c.id());
        // Conservation: every request that reached the cluster boundary is
        // tracked exactly once (a resumed generator must not reissue ids),
        // and nothing arrives that was never generated.
        assert_eq!(
            res.requests_tracked,
            res.requests_arrived,
            "{}: tracked != arrived after the workload swap",
            c.id()
        );
        assert!(
            res.requests_arrived <= res.requests_generated,
            "{}: more arrivals than generated requests",
            c.id()
        );
        assert!(res.requests_generated > 100, "{}: generation stalled", c.id());
    }
}

#[test]
fn campaign_json_is_byte_stable_across_thread_counts() {
    let text = include_str!("../../examples/campaign_smoke.toml");
    let cc = CampaignConfig::parse(text).unwrap();
    assert_eq!(cc.workloads.len(), 2);
    assert_eq!(cc.topologies.len(), 1);
    assert_eq!(cc.conditions.len(), 2);

    let mut serial = cc.clone();
    serial.threads = 1;
    let report = run_campaign(&serial);
    assert_eq!(report.cells.len(), 4, "smoke manifest must expand to 4 permutations");
    let json = report.to_json().render();
    assert!(json.starts_with("{\"schema\":\"dpulens.campaign.v1\""));
    // Every cell carries both tenant SLO lanes with attainment fields.
    assert_eq!(json.matches("\"tenant\":\"interactive\"").count(), 4);
    assert_eq!(json.matches("\"tenant\":\"batch\"").count(), 4);
    assert_eq!(json.matches("\"ttft_attainment\":").count(), 8);

    let mut parallel = cc.clone();
    parallel.threads = 4;
    let json_par = run_campaign(&parallel).to_json().render();
    assert_eq!(json, json_par, "campaign JSON must not depend on --threads");
}
