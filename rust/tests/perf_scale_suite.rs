//! Perf-scale equivalence suite — the headline invariant behind the
//! bucket/sharded event calendar and the parallel observe path: both are
//! pure performance work, so every report schema must stay **byte-identical**
//! to the classic global heap calendar and the serial observe path.
//!
//! * matrix / fleet-v3 / campaign-smoke worlds render the same JSON under
//!   `CalendarKind::Heap` and the default `CalendarKind::Bucket`;
//! * the observe path (parallel telemetry ingest + fleet-sensor rule sweep)
//!   is byte-stable across `observe_threads` 1/2/8, and composes with either
//!   calendar backend;
//! * `perf --fleet-stress --quick` completes its 100-replica point under
//!   `cargo test` (the CI bench-smoke contract);
//! * back-to-back cells share no calendar state (scenario teardown resets).

use dpulens::coordinator::campaign::{run_campaign, CampaignConfig};
use dpulens::coordinator::experiment::standard_cfg;
use dpulens::coordinator::fleet::{run_fleet, FleetConfig, MultiPoolSpec};
use dpulens::coordinator::matrix::{run_matrix, MatrixConfig};
use dpulens::coordinator::perf::{run_perf, stress_cfg, FleetStressConfig, PerfConfig};
use dpulens::coordinator::Scenario;
use dpulens::sim::{CalendarKind, SimDur};

#[test]
fn matrix_json_is_byte_identical_across_calendar_backends() {
    // Trimmed like matrix_suite's determinism test: detection success is
    // irrelevant here, only that the calendar swap changes no byte.
    let mut base = standard_cfg();
    base.duration = SimDur::from_ms(1300);
    base.warmup_windows = 10;
    base.calib_windows = 50;
    let mk = |calendar: CalendarKind| {
        let mut base = base.clone();
        base.calendar = calendar;
        MatrixConfig { base, replicates: 1, threads: 0, negative_control: true, no_reuse: false }
    };
    let heap = run_matrix(&mk(CalendarKind::Heap)).to_json().render();
    let bucket = run_matrix(&mk(CalendarKind::Bucket)).to_json().render();
    assert_eq!(heap, bucket, "matrix JSON differs between calendar backends");
    assert!(heap.contains("\"schema\":\"dpulens.matrix.v1\""));
}

#[test]
fn fleet_v3_json_is_byte_identical_across_calendar_backends() {
    // Mirror run_multipool_study's sweep shape (2-replica base + the 6/2/1
    // multi-pool study block), but drive run_fleet directly so the base
    // config's calendar knob reaches every cell.
    let mk = |calendar: CalendarKind| {
        let mut fc = FleetConfig::new(2);
        fc.multipool = Some(MultiPoolSpec { replicas: 6, prefill_pools: 2, decode_pools: 1 });
        fc.threads = 0;
        fc.base.calendar = calendar;
        fc
    };
    let heap = run_fleet(&mk(CalendarKind::Heap)).to_json().render();
    let bucket = run_fleet(&mk(CalendarKind::Bucket)).to_json().render();
    assert_eq!(heap, bucket, "fleet v3 JSON differs between calendar backends");
    assert!(heap.contains("\"schema\":\"dpulens.fleet.v3\""));
}

#[test]
fn campaign_smoke_json_is_byte_identical_across_calendar_backends() {
    let text = include_str!("../../examples/campaign_smoke.toml");
    let base = CampaignConfig::parse(text).unwrap();
    let mk = |calendar: CalendarKind| {
        let mut cc = base.clone();
        cc.threads = 2;
        cc.calendar = calendar;
        cc
    };
    let heap = run_campaign(&mk(CalendarKind::Heap)).to_json().render();
    let bucket = run_campaign(&mk(CalendarKind::Bucket)).to_json().render();
    assert_eq!(heap, bucket, "campaign JSON differs between calendar backends");
    assert!(heap.starts_with("{\"schema\":\"dpulens.campaign.v1\""));
}

#[test]
fn observe_path_is_byte_stable_across_worker_counts() {
    // A 20-replica multi-pool stress world exercises both parallel observe
    // stages (per-node ingest fan-out + the fleet sensor's rule sweep).
    let digest = |threads: usize, calendar: CalendarKind| {
        let mut cfg = stress_cfg(20, threads, true);
        cfg.calendar = calendar;
        let res = Scenario::new(cfg).run();
        assert!(res.metrics.completed > 0, "stress world served nothing");
        format!(
            "{:?}",
            (
                res.metrics.completed,
                res.telemetry_published,
                res.dpu_ingested,
                res.dpu_invisible_dropped,
                res.windows,
                res.iterations,
                res.replica_iterations,
                res.replica_routed,
                res.detections,
                res.handoffs.started,
                res.handoffs.bytes_delivered,
            )
        )
    };
    let serial = digest(1, CalendarKind::Bucket);
    assert_eq!(serial, digest(2, CalendarKind::Bucket), "2 workers diverged");
    assert_eq!(serial, digest(8, CalendarKind::Bucket), "8 workers diverged");
    // The observe fan-out composes with the calendar swap: still identical.
    assert_eq!(serial, digest(8, CalendarKind::Heap), "heap + workers diverged");
}

#[test]
fn quick_fleet_stress_completes_the_100_replica_point() {
    let cfg = PerfConfig {
        ingest_events: 4_000,
        ingest_batch: 256,
        snapshot_windows: 8,
        snapshot_events_per_window: 200,
        matrix_replicates: 1,
        fleet_replicas: 2,
        threads: 0,
        micro_only: true,
        quick: true,
        fleet_stress: Some(FleetStressConfig::quick(0)),
    };
    let rep = run_perf(&cfg);
    let fs = rep.fleet_stress.as_ref().expect("fleet-stress must run");
    assert_eq!(fs.points.len(), 1);
    let p = &fs.points[0];
    assert_eq!(p.replicas, 100);
    assert!(p.completed > 0, "100-replica world served nothing");
    assert!(p.events > 0, "100-replica world published no telemetry");
    let json = rep.to_json().render();
    assert!(json.contains("\"schema\":\"dpulens.perf.v4\""));
    assert!(json.contains("\"replicas\":100"));
    assert!(!json.contains("NaN") && !json.contains("inf"));
}

#[test]
fn back_to_back_cells_share_no_calendar_state() {
    // Teardown resets the calendar (clock, sequence, counters); two
    // consecutive cells of the same config must be bit-equal.
    let run = || {
        let res = Scenario::new(stress_cfg(20, 2, true)).run();
        (res.metrics.completed, res.telemetry_published, res.detections.len())
    };
    assert_eq!(run(), run(), "a fresh cell was affected by its predecessor");
}
