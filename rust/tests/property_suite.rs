//! Property-based integration suite (in-repo harness; proptest is not
//! vendored offline). Invariants that must hold for ANY workload shape:
//! sim-event ordering, telemetry conservation, KV/batcher/router state,
//! streaming-statistics correctness against exact computation.

use dpulens::prop_assert;
use dpulens::sim::{Engine, SimTime};
use dpulens::util::prop::{check, PropConfig};
use dpulens::util::rng::Rng;
use dpulens::util::stats::{P2Quantile, Summary, Welford};

#[test]
fn prop_sim_engine_total_order() {
    check("sim-total-order", PropConfig::default().cases(48), |g| {
        let mut e: Engine<u64> = Engine::new();
        let n = g.usize_in(1, 400);
        for i in 0..n {
            e.schedule_at(SimTime(g.rng.below(10_000)), i as u64);
        }
        let mut last_t = SimTime::ZERO;
        let mut seen = std::collections::HashSet::new();
        let mut count = 0;
        while let Some((t, p)) = e.pop() {
            prop_assert!(t >= last_t, "time regressed {t:?} < {last_t:?}");
            prop_assert!(seen.insert(p), "payload {p} delivered twice");
            last_t = t;
            count += 1;
        }
        prop_assert!(count == n, "delivered {count} != scheduled {n}");
        Ok(())
    });
}

#[test]
fn prop_sim_ties_preserve_insertion_order() {
    check("sim-fifo-ties", PropConfig::default().cases(32), |g| {
        let mut e: Engine<usize> = Engine::new();
        let t = SimTime(g.rng.below(100));
        let n = g.usize_in(2, 100);
        for i in 0..n {
            e.schedule_at(t, i);
        }
        let order: Vec<usize> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        prop_assert!(order == (0..n).collect::<Vec<_>>(), "ties reordered: {order:?}");
        Ok(())
    });
}

#[test]
fn prop_welford_matches_exact() {
    check("welford-exact", PropConfig::default().cases(64), |g| {
        let xs = g.vec_of(|r: &mut Rng| {
            let mu = r.range_f64(-100.0, 100.0);
            r.normal_ms(mu, 5.0)
        });
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        prop_assert!((w.mean() - mean).abs() < 1e-6 * (1.0 + mean.abs()), "mean mismatch");
        prop_assert!((w.variance() - var).abs() < 1e-6 * (1.0 + var), "var mismatch");
        let mn = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        prop_assert!(w.min() == mn && w.max() == mx, "min/max mismatch");
        Ok(())
    });
}

#[test]
fn prop_p2_quantile_tracks_exact_median() {
    check("p2-median", PropConfig::default().cases(24), |g| {
        let n = g.usize_in(200, 3000);
        let mut p2 = P2Quantile::new(0.5);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let x = g.rng.exponential(0.5);
            p2.push(x);
            v.push(x);
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = v[v.len() / 2];
        let err = (p2.value() - exact).abs() / exact.max(1e-9);
        prop_assert!(err < 0.35, "p2 median err {err:.2} (p2={} exact={exact})", p2.value());
        Ok(())
    });
}

#[test]
fn prop_summary_percentiles_ordered() {
    check("summary-order", PropConfig::default().cases(48), |g| {
        let mut s = Summary::new();
        let n = g.usize_in(1, 500);
        for _ in 0..n {
            s.push(g.rng.pareto(1.0, 1.2));
        }
        prop_assert!(s.p50() <= s.p95() + 1e-12, "p50 > p95");
        prop_assert!(s.p95() <= s.p99() + 1e-12, "p95 > p99");
        prop_assert!(s.min() <= s.p50() && s.p99() <= s.max(), "bounds violated");
        Ok(())
    });
}

#[test]
fn prop_full_scenario_conservation_under_random_workloads() {
    // The big one: ANY workload shape keeps the system's accounting exact.
    check("scenario-conservation", PropConfig::default().cases(6), |g| {
        use dpulens::coordinator::{Scenario, ScenarioCfg};
        use dpulens::sim::dist::{Arrival, LengthDist};
        use dpulens::sim::SimDur;

        let mut cfg = ScenarioCfg::default();
        cfg.seed = g.rng.next_u64();
        cfg.duration = SimDur::from_ms(400);
        cfg.warmup_windows = 5;
        cfg.calib_windows = 10;
        cfg.workload.arrival = Arrival::Poisson { rate: g.f64_in(50.0, 800.0) };
        cfg.workload.prompt_len =
            LengthDist::Uniform { lo: 2, hi: g.usize_in(8, 64) };
        cfg.workload.output_len = if g.bool() {
            LengthDist::Uniform { lo: 1, hi: g.usize_in(4, 24) }
        } else {
            LengthDist::Bimodal { short: 2, long: g.usize_in(16, 48), p_short: 0.5 }
        };
        cfg.engine.policy.continuous = g.bool();
        cfg.engine.policy.length_bucketing = g.bool();
        cfg.engine.policy.inflight_remap = g.bool();
        let res = Scenario::new(cfg).run();

        prop_assert!(
            res.dpu_ingested + res.dpu_invisible_dropped == res.telemetry_published,
            "telemetry leak: {} + {} != {}",
            res.dpu_ingested,
            res.dpu_invisible_dropped,
            res.telemetry_published
        );
        prop_assert!(
            res.metrics.tokens_out >= res.metrics.completed,
            "completed requests without tokens"
        );
        // TTFT percentiles ordered and finite.
        let (p50, p99) = (res.metrics.ttft_ns.p50(), res.metrics.ttft_ns.p99());
        prop_assert!(p50.is_finite() && p99.is_finite() && p50 <= p99 + 1e-9,
            "TTFT percentiles broken: p50={p50} p99={p99}");
        Ok(())
    });
}

#[test]
fn prop_fastmap_model_check() {
    // FastMap must behave exactly like std HashMap under random ops.
    check("fastmap-model", PropConfig::default().cases(48), |g| {
        let mut fast: dpulens::util::FastMap<u32, u64> = Default::default();
        let mut model: std::collections::HashMap<u32, u64> = Default::default();
        for _ in 0..300 {
            let k = g.rng.below(64) as u32;
            match g.rng.below(3) {
                0 => {
                    let v = g.rng.next_u64();
                    fast.insert(k, v);
                    model.insert(k, v);
                }
                1 => {
                    prop_assert!(fast.remove(&k) == model.remove(&k), "remove diverged");
                }
                _ => {
                    prop_assert!(fast.get(&k) == model.get(&k), "get diverged for {k}");
                }
            }
            prop_assert!(fast.len() == model.len(), "len diverged");
        }
        Ok(())
    });
}

// ---- two-stage (phase-disaggregated) router properties ----

/// A small disaggregated scenario: 2 prefill TP4x1 + 1 decode TP4x2 on four
/// nodes, tiny model, bounded request count so runs drain fully.
fn small_disagg_cfg(seed: u64, max_requests: usize) -> dpulens::coordinator::ScenarioCfg {
    use dpulens::cluster::{ReplicaRole, ReplicaShape};
    use dpulens::coordinator::ScenarioCfg;
    use dpulens::sim::dist::{Arrival, LengthDist};
    use dpulens::sim::SimDur;
    let mut cfg = ScenarioCfg::default();
    cfg.seed = seed;
    cfg.duration = SimDur::from_ms(2000);
    cfg.warmup_windows = 5;
    cfg.calib_windows = 20;
    cfg.max_requests = max_requests;
    cfg.cluster.n_nodes = 4;
    cfg.cluster.pp_degree = 2;
    cfg.engine.shapes = Some(vec![
        ReplicaShape::new(ReplicaRole::Prefill, 4, 1),
        ReplicaShape::new(ReplicaRole::Prefill, 4, 1),
        ReplicaShape::new(ReplicaRole::Decode, 4, 2),
    ]);
    cfg.workload.arrival = Arrival::Poisson { rate: 400.0 };
    cfg.workload.prompt_len = LengthDist::Uniform { lo: 8, hi: 32 };
    cfg.workload.output_len = LengthDist::Uniform { lo: 2, hi: 8 };
    cfg
}

#[test]
fn prop_disagg_no_request_loss_and_kv_bytes_conserved() {
    // Across seeds: every generated request reaches a terminal state (no
    // request is lost at the prefill->decode boundary), every handoff that
    // started also landed (the run drains), and handoff bytes conserve
    // exactly: bytes out of the prefill pool == bytes into the decode pool.
    check("disagg-conservation", PropConfig::default().cases(6), |g| {
        let seed = g.rng.next_u64() | 1;
        let n = 40 + g.usize_in(0, 40);
        let res = dpulens::coordinator::Scenario::new(small_disagg_cfg(seed, n)).run();
        prop_assert!(
            res.metrics.completed + res.metrics.rejected == n as u64,
            "request loss: {} done + {} rejected != {n} generated (seed {seed})",
            res.metrics.completed,
            res.metrics.rejected
        );
        prop_assert!(
            res.handoffs.completed == res.handoffs.started,
            "handoffs stranded in flight: {}/{} (seed {seed})",
            res.handoffs.completed,
            res.handoffs.started
        );
        prop_assert!(
            res.handoffs.bytes_delivered == res.handoffs.bytes_sent,
            "KV bytes not conserved: {} sent vs {} delivered (seed {seed})",
            res.handoffs.bytes_sent,
            res.handoffs.bytes_delivered
        );
        prop_assert!(res.handoffs_parked_at_end == 0, "handoffs parked at end (seed {seed})");
        prop_assert!(res.handoffs.started > 0, "no handoffs at all (seed {seed})");
        // Per-replica arrival accounting sums to the completed handoffs.
        let arrivals: u64 = res.handoffs.arrivals_per_replica.iter().sum();
        prop_assert!(
            arrivals == res.handoffs.completed,
            "arrival accounting diverged (seed {seed})"
        );
        Ok(())
    });
}

#[test]
fn prop_draining_a_prefill_replica_never_strands_requests() {
    // With prefill replica 0 drained, admissions must all land on replica 1
    // and every request still completes — nothing routes into, or strands
    // on, the drained replica.
    check("disagg-drain", PropConfig::default().cases(4), |g| {
        let seed = g.rng.next_u64() | 1;
        let n = 40;
        let mut s = dpulens::coordinator::Scenario::new(small_disagg_cfg(seed, n));
        s.engine.router.set_drained(0, true);
        let res = s.run();
        prop_assert!(
            res.replica_routed[0] == 0,
            "drained prefill replica still admitted {} (seed {seed})",
            res.replica_routed[0]
        );
        prop_assert!(
            res.metrics.completed + res.metrics.rejected == n as u64,
            "drain stranded requests: {} + {} != {n} (seed {seed})",
            res.metrics.completed,
            res.metrics.rejected
        );
        Ok(())
    });
}

#[test]
fn prop_two_stage_router_pools_and_accounting() {
    // Engine-level: admissions only ever land in the prefill pool, phase
    // transitions only in the decode pool, and each stage's outstanding
    // accounting conserves independently.
    use dpulens::cluster::{ClusterSpec, ReplicaRole, ReplicaShape};
    use dpulens::engine::{build_shaped_replicas, Engine, EngineConfig};
    use dpulens::ids::{FlowId, ReqId};
    use dpulens::workload::request::InferenceRequest;
    check("two-stage-router", PropConfig::default().cases(32), |g| {
        let n_prefill = g.usize_in(1, 2);
        let n_decode = g.usize_in(1, 2);
        let mut spec = ClusterSpec::default();
        spec.n_nodes = n_prefill + 2 * n_decode;
        spec.pp_degree = 2.min(spec.n_nodes);
        let mut shapes = Vec::new();
        for _ in 0..n_prefill {
            shapes.push(ReplicaShape::new(ReplicaRole::Prefill, 4, 1));
        }
        for _ in 0..n_decode {
            shapes.push(ReplicaShape::new(ReplicaRole::Decode, 4, 2));
        }
        let mut cfg = EngineConfig::default();
        cfg.shapes = Some(shapes.clone());
        let plans = build_shaped_replicas(&spec, &shapes);
        let mut e = Engine::new(cfg, plans);
        let mut live_prefill: Vec<(ReqId, usize)> = Vec::new();
        let mut live_decode: Vec<(ReqId, usize)> = Vec::new();
        let mut next = 0u32;
        for _ in 0..200 {
            let coin = g.rng.f64();
            if coin < 0.5 {
                let id = ReqId(next);
                let flow = FlowId(g.rng.below(32) as u32);
                next += 1;
                let req = InferenceRequest::new(
                    id,
                    flow,
                    dpulens::sim::SimTime(0),
                    vec![1, 2, 3],
                    4,
                );
                let p = e.register(req);
                prop_assert!(p < n_prefill, "admission left the prefill pool: {p}");
                live_prefill.push((id, p));
            } else if coin < 0.8 && !live_prefill.is_empty() {
                // Phase transition: prefill done, route to the decode pool.
                let idx = g.rng.index(live_prefill.len());
                let (id, p) = live_prefill.swap_remove(idx);
                e.router.complete(p);
                let d = e.route_decode(id);
                prop_assert!(
                    d >= n_prefill,
                    "transition left the decode pool: {d} (pools {n_prefill}+{n_decode})"
                );
                live_decode.push((id, d));
            } else if !live_decode.is_empty() {
                let idx = g.rng.index(live_decode.len());
                let (_, d) = live_decode.swap_remove(idx);
                e.decode_router.complete(d);
            }
            let pre: i64 = e.router.outstanding().iter().sum();
            let dec: i64 = e.decode_router.outstanding().iter().sum();
            prop_assert!(
                pre == live_prefill.len() as i64,
                "prefill outstanding {pre} != {}",
                live_prefill.len()
            );
            prop_assert!(
                dec == live_decode.len() as i64,
                "decode outstanding {dec} != {}",
                live_decode.len()
            );
        }
        Ok(())
    });
}
