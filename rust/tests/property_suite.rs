//! Property-based integration suite (in-repo harness; proptest is not
//! vendored offline). Invariants that must hold for ANY workload shape:
//! sim-event ordering, telemetry conservation, KV/batcher/router state,
//! streaming-statistics correctness against exact computation.

use dpulens::prop_assert;
use dpulens::sim::{Engine, SimTime};
use dpulens::util::prop::{check, PropConfig};
use dpulens::util::rng::Rng;
use dpulens::util::stats::{P2Quantile, Summary, Welford};

#[test]
fn prop_sim_engine_total_order() {
    check("sim-total-order", PropConfig::default().cases(48), |g| {
        let mut e: Engine<u64> = Engine::new();
        let n = g.usize_in(1, 400);
        for i in 0..n {
            e.schedule_at(SimTime(g.rng.below(10_000)), i as u64);
        }
        let mut last_t = SimTime::ZERO;
        let mut seen = std::collections::HashSet::new();
        let mut count = 0;
        while let Some((t, p)) = e.pop() {
            prop_assert!(t >= last_t, "time regressed {t:?} < {last_t:?}");
            prop_assert!(seen.insert(p), "payload {p} delivered twice");
            last_t = t;
            count += 1;
        }
        prop_assert!(count == n, "delivered {count} != scheduled {n}");
        Ok(())
    });
}

#[test]
fn prop_sim_ties_preserve_insertion_order() {
    check("sim-fifo-ties", PropConfig::default().cases(32), |g| {
        let mut e: Engine<usize> = Engine::new();
        let t = SimTime(g.rng.below(100));
        let n = g.usize_in(2, 100);
        for i in 0..n {
            e.schedule_at(t, i);
        }
        let order: Vec<usize> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        prop_assert!(order == (0..n).collect::<Vec<_>>(), "ties reordered: {order:?}");
        Ok(())
    });
}

#[test]
fn prop_welford_matches_exact() {
    check("welford-exact", PropConfig::default().cases(64), |g| {
        let xs = g.vec_of(|r: &mut Rng| {
            let mu = r.range_f64(-100.0, 100.0);
            r.normal_ms(mu, 5.0)
        });
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        prop_assert!((w.mean() - mean).abs() < 1e-6 * (1.0 + mean.abs()), "mean mismatch");
        prop_assert!((w.variance() - var).abs() < 1e-6 * (1.0 + var), "var mismatch");
        let mn = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        prop_assert!(w.min() == mn && w.max() == mx, "min/max mismatch");
        Ok(())
    });
}

#[test]
fn prop_p2_quantile_tracks_exact_median() {
    check("p2-median", PropConfig::default().cases(24), |g| {
        let n = g.usize_in(200, 3000);
        let mut p2 = P2Quantile::new(0.5);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let x = g.rng.exponential(0.5);
            p2.push(x);
            v.push(x);
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = v[v.len() / 2];
        let err = (p2.value() - exact).abs() / exact.max(1e-9);
        prop_assert!(err < 0.35, "p2 median err {err:.2} (p2={} exact={exact})", p2.value());
        Ok(())
    });
}

#[test]
fn prop_summary_percentiles_ordered() {
    check("summary-order", PropConfig::default().cases(48), |g| {
        let mut s = Summary::new();
        let n = g.usize_in(1, 500);
        for _ in 0..n {
            s.push(g.rng.pareto(1.0, 1.2));
        }
        prop_assert!(s.p50() <= s.p95() + 1e-12, "p50 > p95");
        prop_assert!(s.p95() <= s.p99() + 1e-12, "p95 > p99");
        prop_assert!(s.min() <= s.p50() && s.p99() <= s.max(), "bounds violated");
        Ok(())
    });
}

#[test]
fn prop_full_scenario_conservation_under_random_workloads() {
    // The big one: ANY workload shape keeps the system's accounting exact.
    check("scenario-conservation", PropConfig::default().cases(6), |g| {
        use dpulens::coordinator::{Scenario, ScenarioCfg};
        use dpulens::sim::dist::{Arrival, LengthDist};
        use dpulens::sim::SimDur;

        let mut cfg = ScenarioCfg::default();
        cfg.seed = g.rng.next_u64();
        cfg.duration = SimDur::from_ms(400);
        cfg.warmup_windows = 5;
        cfg.calib_windows = 10;
        cfg.workload.arrival = Arrival::Poisson { rate: g.f64_in(50.0, 800.0) };
        cfg.workload.prompt_len =
            LengthDist::Uniform { lo: 2, hi: g.usize_in(8, 64) };
        cfg.workload.output_len = if g.bool() {
            LengthDist::Uniform { lo: 1, hi: g.usize_in(4, 24) }
        } else {
            LengthDist::Bimodal { short: 2, long: g.usize_in(16, 48), p_short: 0.5 }
        };
        cfg.engine.policy.continuous = g.bool();
        cfg.engine.policy.length_bucketing = g.bool();
        cfg.engine.policy.inflight_remap = g.bool();
        let res = Scenario::new(cfg).run();

        prop_assert!(
            res.dpu_ingested + res.dpu_invisible_dropped == res.telemetry_published,
            "telemetry leak: {} + {} != {}",
            res.dpu_ingested,
            res.dpu_invisible_dropped,
            res.telemetry_published
        );
        prop_assert!(
            res.metrics.tokens_out >= res.metrics.completed,
            "completed requests without tokens"
        );
        // TTFT percentiles ordered and finite.
        let (p50, p99) = (res.metrics.ttft_ns.p50(), res.metrics.ttft_ns.p99());
        prop_assert!(p50.is_finite() && p99.is_finite() && p50 <= p99 + 1e-9,
            "TTFT percentiles broken: p50={p50} p99={p99}");
        Ok(())
    });
}

#[test]
fn prop_fastmap_model_check() {
    // FastMap must behave exactly like std HashMap under random ops.
    check("fastmap-model", PropConfig::default().cases(48), |g| {
        let mut fast: dpulens::util::FastMap<u32, u64> = Default::default();
        let mut model: std::collections::HashMap<u32, u64> = Default::default();
        for _ in 0..300 {
            let k = g.rng.below(64) as u32;
            match g.rng.below(3) {
                0 => {
                    let v = g.rng.next_u64();
                    fast.insert(k, v);
                    model.insert(k, v);
                }
                1 => {
                    prop_assert!(fast.remove(&k) == model.remove(&k), "remove diverged");
                }
                _ => {
                    prop_assert!(fast.get(&k) == model.get(&k), "get diverged for {k}");
                }
            }
            prop_assert!(fast.len() == model.len(), "len diverged");
        }
        Ok(())
    });
}
