//! E2E coverage for `coordinator::fleet` — the fleet-scale serving plane
//! (data-parallel replicas × routing policies) and the DP condition family:
//!
//! * on a ≥2-replica cluster, all three DP conditions (router flow skew,
//!   hot-replica KV exhaustion, straggler replica) are detected from the
//!   router/LB vantage and mitigated by the closed loop, with
//!   post-mitigation throughput recovering above the injected level;
//! * the fleet JSON (`dpulens fleet --json`) is byte-identical across
//!   repeated runs and across worker-thread counts.

use dpulens::coordinator::fleet::{fleet_base_cfg, run_fleet, FleetConfig};
use dpulens::dpu::detectors::{Condition, DP_CONDITIONS};
use dpulens::engine::RoutePolicy;
use dpulens::sim::SimDur;

#[test]
fn dp_family_detected_and_mitigated_on_multi_replica_fleet() {
    let fc = FleetConfig::new(3);
    let report = run_fleet(&fc);

    assert_eq!(report.replicas, 3);
    assert_eq!(report.dp_rows.len(), DP_CONDITIONS.len());
    for row in &report.dp_rows {
        assert!(
            row.detected,
            "{} not detected on the 3-replica fleet",
            row.condition.id()
        );
        assert!(
            row.latency_ns.is_some(),
            "{} detected but no time-to-detect sample",
            row.condition.id()
        );
        assert!(
            row.actions >= 1,
            "{} fired but the controller took no action",
            row.condition.id()
        );
        assert!(row.injected_tok_per_s > 0.0, "{} served nothing", row.condition.id());
        // The acceptance bar: post-mitigation throughput recovers.
        assert!(
            row.mitigated_tok_per_s > row.injected_tok_per_s * 1.03,
            "{}: mitigated {:.0} tok/s did not recover over injected {:.0} tok/s",
            row.condition.id(),
            row.mitigated_tok_per_s,
            row.injected_tok_per_s
        );
    }

    // The cross-replica skew study: DP1 concentrates served tokens on the
    // hot replica; mitigation spreads them back out.
    let dp1 = report
        .dp_rows
        .iter()
        .find(|r| r.condition == Condition::Dp1RouterFlowSkew)
        .unwrap();
    assert!(
        dp1.injected_token_skew > 1.15,
        "DP1 injection produced no visible replica skew: {:.2}",
        dp1.injected_token_skew
    );
    assert!(
        dp1.mitigated_token_skew < dp1.injected_token_skew,
        "mitigation did not reduce DP1 skew: {:.2} -> {:.2}",
        dp1.injected_token_skew,
        dp1.mitigated_token_skew
    );

    // Healthy policy rows: every policy serves the uniform workload with
    // bounded cross-replica skew, and every replica participates.
    assert_eq!(report.policy_rows.len(), 5);
    for row in &report.policy_rows {
        assert!(row.completed > 100, "{} barely served", row.policy.id());
        assert!(
            row.token_skew < 2.5,
            "{} skew {:.2} out of bounds on a uniform workload",
            row.policy.id(),
            row.token_skew
        );
        assert!(
            row.replica_tokens.iter().all(|&t| t > 0),
            "{} starved a replica: {:?}",
            row.policy.id(),
            row.replica_tokens
        );
    }
    // The balanced policies keep arrival shares tighter than affinity hash.
    let share_of = |p: RoutePolicy| {
        report.policy_rows.iter().find(|r| r.policy == p).unwrap().max_flow_share
    };
    assert!(share_of(RoutePolicy::RoundRobin) <= share_of(RoutePolicy::FlowHash) + 0.02);
    assert!(share_of(RoutePolicy::LeastLoaded) <= share_of(RoutePolicy::FlowHash) + 0.02);
}

#[test]
fn fleet_json_is_deterministic_across_threads() {
    // Trimmed scenario so this stays cheap: detection success is irrelevant
    // here, only bit-stable aggregation and serialization.
    let mut base = fleet_base_cfg(2);
    base.duration = SimDur::from_ms(1500);
    base.warmup_windows = 10;
    base.calib_windows = 50;

    let mk = |threads: usize| FleetConfig {
        base: base.clone(),
        replicas: 2,
        policies: vec![RoutePolicy::FlowHash, RoutePolicy::PowerOfTwo],
        threads,
        disagg: false,
        multipool: None,
        telemetry_faults: false,
        no_reuse: false,
    };

    let a = run_fleet(&mk(2)).to_json().render();
    let b = run_fleet(&mk(3)).to_json().render();
    assert_eq!(a, b, "fleet JSON differs across runs/thread counts");
    assert!(a.contains("\"schema\":\"dpulens.fleet.v1\""));
    assert!(a.contains("\"replicas\":2"));
    assert!(a.contains("\"po2\""));
}
