//! Offline stub of the `xla` (xla-rs) PJRT binding surface.
//!
//! The real binding links `libxla_extension`, which is not vendorable in this
//! offline environment. This crate reproduces exactly the API surface
//! `dpulens::runtime` compiles against, so `--features pjrt` builds
//! everywhere; every runtime entry point returns a descriptive error instead
//! of executing. To run the AOT artifacts for real, point the `xla` path
//! dependency in the workspace `Cargo.toml` at an actual xla-rs checkout (or
//! use a `[patch]` section) — no `dpulens` source change is needed.

use std::borrow::Borrow;
use std::fmt;

const STUB_MSG: &str = "xla stub: built `pjrt` against the bundled no-op xla crate; \
     point the `xla` path dependency at a real xla-rs binding (with \
     libxla_extension) to execute AOT artifacts";

/// Error type matching the real binding's `xla::Error` role.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub() -> Self {
        Error(STUB_MSG.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a `Literal` can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// Host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    _stub: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _stub: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::stub())
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto {
    _stub: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _stub: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _stub: () }
    }
}

/// PJRT client (CPU plugin in the real binding).
#[derive(Debug)]
pub struct PjRtClient {
    _stub: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _stub: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// Device-resident buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _stub: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surface_errors_not_panics() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("stub"));
    }
}
