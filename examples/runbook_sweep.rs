//! Sweep all 28 runbook conditions (Tables 3a-c): inject each, report
//! detection, latency, serving impact, and the mapped directive — the
//! quick-look version of the bench suite.
//!
//!     cargo run --release --example runbook_sweep [-- --mitigate]

use dpulens::coordinator::experiment::{
    condition_experiment, report_header, report_row, standard_cfg,
};
use dpulens::dpu::detectors::{Condition, ALL_CONDITIONS};
use dpulens::engine::preset;
use dpulens::util::table::Table;

/// Per-condition scenario shaping (see DESIGN.md §4).
fn cfg_for(c: Condition) -> dpulens::coordinator::ScenarioCfg {
    let mut cfg = standard_cfg();
    match c {
        // Compute-skew conditions need a compute-dominated cost profile for
        // a straggler/mispartition to move collective timing.
        Condition::Ew1TpStraggler
        | Condition::Ew3CrossNodeSkew
        | Condition::Ew4Congestion
        | Condition::Ew9EarlyStopSkew => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 150.0 };
        }
        // Pipeline-cadence detection needs a *busy* pipeline: idle lulls
        // produce ms-scale healthy gaps that mask a mispartitioned stage.
        Condition::Ew2PpBubble => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 500.0 };
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
        }
        // Early-stop conditions only bite when decode slots are saturated.
        Condition::Ns8EarlyCompletion => {
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 2000.0 };
            cfg.workload.prompt_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 24 };
        }
        // PC10's PCIe signature (shrinking decode D2H blocks) additionally
        // needs iterations slow enough that slots actually fill: use the
        // compute-heavy profile under sustained demand.
        Condition::Pc10DecodeEarlyStop => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 1500.0 };
            cfg.workload.prompt_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 24 };
        }
        _ => {}
    }
    cfg
}

fn main() {
    let mitigate = std::env::args().any(|a| a == "--mitigate");
    let mut t = Table::new("runbook sweep — all 28 conditions").header(&report_header());
    let mut detected = 0;
    for c in ALL_CONDITIONS {
        let cfg = cfg_for(c);
        let rep = condition_experiment(c, &cfg, mitigate);
        if rep.detected {
            detected += 1;
        }
        eprintln!(
            "  {}: detected={} impact={:.2}x",
            c.id(),
            rep.detected,
            rep.throughput_impact()
        );
        t.row(report_row(&rep));
    }
    print!("{}", t.render());
    println!("detected {detected}/28 conditions from the DPU vantage point");
}
