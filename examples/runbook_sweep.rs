//! Sweep all 28 runbook conditions (Tables 3a-c): inject each, report
//! detection, latency, serving impact, and the mapped directive — the
//! quick-look version of the bench suite, fanned out over worker threads by
//! the shared `coordinator::matrix` subsystem (which also owns the
//! per-condition scenario shaping).
//!
//!     cargo run --release --example runbook_sweep [-- --mitigate]

use dpulens::coordinator::experiment::{report_header, report_row, standard_cfg};
use dpulens::coordinator::matrix::run_sweep;
use dpulens::util::table::Table;

fn main() {
    let mitigate = std::env::args().any(|a| a == "--mitigate");
    let base = standard_cfg();
    let t0 = std::time::Instant::now();
    let reports = run_sweep(&base, mitigate, 0);
    let mut t = Table::new("runbook sweep — all 28 conditions").header(&report_header());
    let mut detected = 0;
    for rep in &reports {
        if rep.detected {
            detected += 1;
        }
        eprintln!(
            "  {}: detected={} impact={:.2}x",
            rep.condition.id(),
            rep.detected,
            rep.throughput_impact()
        );
        t.row(report_row(rep));
    }
    print!("{}", t.render());
    println!(
        "detected {detected}/28 conditions from the DPU vantage point ({:.1}s wallclock)",
        t0.elapsed().as_secs_f64()
    );
}
