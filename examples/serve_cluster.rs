//! End-to-end driver (DESIGN.md §6 / EXPERIMENTS.md §E6): load the REAL
//! AOT-compiled transformer (prefill + decode HLO via PJRT-CPU), serve
//! batched requests from the embedded corpus over the simulated 4-node
//! cluster, and report throughput/latency — healthy, under an injected
//! pathology, and with the DPU closed loop mitigating it.
//!
//! Requires artifacts: `make artifacts` first. Run:
//!
//!     cargo run --release --example serve_cluster

use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::dpu::detectors::Condition;
use dpulens::engine::ComputeBackend;
use dpulens::metrics::ServeMetrics;
use dpulens::runtime::{cpu_client, ArtifactSet, TransformerSession};
use dpulens::sim::{SimDur, SimTime, MS};
use dpulens::util::table::Table;
use dpulens::workload::tokenizer::ToyTokenizer;

fn cfg_base() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(900);
    cfg.calib_windows = 150;
    cfg.max_requests = 96; // bound real-compute wallclock
    cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 400.0 };
    cfg.workload.prompt_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 48 };
    cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 4, hi: 10 };
    cfg
}

fn real_backends(cfg: &ScenarioCfg) -> Vec<Box<dyn ComputeBackend>> {
    let client = cpu_client().expect("PJRT CPU client");
    let arts = ArtifactSet::open_default().expect("run `make artifacts` first");
    println!(
        "loaded artifacts: preset={} ({} layers, d={}, vocab={}), batch={}",
        arts.manifest.preset,
        arts.manifest.layers,
        arts.manifest.d_model,
        arts.manifest.vocab,
        arts.manifest.batch
    );
    let n_rep = dpulens::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage).len();
    (0..n_rep)
        .map(|_| {
            Box::new(TransformerSession::load(&client, &arts).expect("compile artifacts"))
                as Box<dyn ComputeBackend>
        })
        .collect()
}

fn main() {
    println!("=== dpulens end-to-end: real compiled transformer over the simulated cluster ===\n");

    // Show a real generation first: tokens in, tokens out, through PJRT.
    {
        let client = cpu_client().expect("PJRT CPU client");
        let arts = ArtifactSet::open_default().expect("run `make artifacts` first");
        let mut session = TransformerSession::load(&client, &arts).expect("load");
        let tok = ToyTokenizer::new(arts.manifest.vocab);
        let prompt_text = dpulens::workload::corpus::prompt(0);
        let prompt = tok.encode(prompt_text);
        let n = prompt.len().min(arts.manifest.prefill_len);
        let slots = [0usize];
        let first = session.prefill(&slots, &[prompt[..n].to_vec()]);
        let mut generated = vec![first[0]];
        let mut pos = n as u32;
        for _ in 0..8 {
            let next = session.decode(&slots, &[*generated.last().unwrap()], &[pos]);
            generated.push(next[0]);
            pos += 1;
        }
        println!("prompt ({} tokens): {:.60}...", n, prompt_text);
        println!("generated ids via compiled HLO: {}", tok.render(&generated));
        println!(
            "(PJRT calls so far: {} prefill, {} decode)\n",
            session.prefill_calls, session.decode_calls
        );
    }

    let mut table = Table::new("E6: end-to-end serving (real compute)")
        .header(&ServeMetrics::table_header());

    // Phase 1: healthy.
    let cfg = cfg_base();
    let res_healthy = Scenario::with_backends(cfg.clone(), real_backends(&cfg)).run();
    println!("[healthy]   {}", res_healthy.metrics.brief());
    table.row(res_healthy.metrics.row_cells("healthy"));

    // Phase 2: PC1 (H2D starvation) injected, no mitigation.
    let mut cfg_inj = cfg_base();
    cfg_inj.inject = Some((Condition::Pc1H2dStarvation, SimTime(350 * MS)));
    let res_inj = Scenario::with_backends(cfg_inj.clone(), real_backends(&cfg_inj)).run();
    println!(
        "[injected]  {} | detected PC1: {}",
        res_inj.metrics.brief(),
        res_inj.detected(Condition::Pc1H2dStarvation)
    );
    table.row(res_inj.metrics.row_cells("PC1 injected"));

    // Phase 3: same injection, closed loop on.
    let mut cfg_mit = cfg_inj.clone();
    cfg_mit.mitigate = true;
    let res_mit = Scenario::with_backends(cfg_mit.clone(), real_backends(&cfg_mit)).run();
    println!(
        "[mitigated] {} | actions: {:?}",
        res_mit.metrics.brief(),
        res_mit.actions.iter().map(|a| format!("{:?}", a.directive)).collect::<Vec<_>>()
    );
    table.row(res_mit.metrics.row_cells("PC1 + closed loop"));

    println!("\n{}", table.render());
    let lat = res_inj
        .detection_latency(Condition::Pc1H2dStarvation)
        .map(|d| format!("{d}"))
        .unwrap_or_else(|| "-".into());
    println!("PC1 detection latency: {lat}");
    println!(
        "tok/s: healthy {:.0} -> injected {:.0} -> mitigated {:.0}",
        res_healthy.metrics.tok_per_s(),
        res_inj.metrics.tok_per_s(),
        res_mit.metrics.tok_per_s()
    );
    println!("\nreal compute: {}", res_healthy.real_compute);
}
