//! Quickstart: spin up a simulated 4-node × 4-GPU cluster serving an LLM
//! workload, let the DPU plane calibrate, and print what it sees.
//!
//!     cargo run --release --example quickstart

use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::metrics::ServeMetrics;
use dpulens::sim::SimDur;
use dpulens::util::table::Table;

fn main() {
    // A healthy scenario: Poisson arrivals, mixed prompt/output lengths,
    // continuous batching with paged KV over a TP×PP plan.
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(800);
    cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 300.0 };

    println!("dpulens quickstart — simulated cluster, DPU plane observing\n");
    let res = Scenario::new(cfg).run();

    let mut t = Table::new("serving").header(&ServeMetrics::table_header());
    t.row(res.metrics.row_cells("healthy"));
    print!("{}", t.render());

    println!("\ntelemetry plane:");
    println!("  events published:      {}", res.telemetry_published);
    println!("  DPU-visible ingested:  {}", res.dpu_ingested);
    println!("  invisible (paper 4.3): {}  <- NVLink / intra-GPU / CPU-local", res.dpu_invisible_dropped);
    println!("  windows processed:     {}", res.windows);

    let mut classes: Vec<_> = res.class_counts.iter().collect();
    classes.sort_by_key(|(_, n)| std::cmp::Reverse(**n));
    println!("\ntop telemetry classes:");
    for (class, n) in classes.iter().take(8) {
        println!("  {class:<14} {n}");
    }

    println!(
        "\ndetections on a healthy cluster: {} (the baseline holds)",
        res.detections.len()
    );
    println!("\nNext: `cargo run --release --example pathology_demo` to break it.");
}
