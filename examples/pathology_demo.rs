//! Pathology demo: inject a TP straggler (EW1), watch the DPU plane detect
//! it from collective-burst arrival spreads, corroborate with the PCIe
//! vantage, attribute the root cause (paper §4.2), and close the loop.
//!
//!     cargo run --release --example pathology_demo

use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::dpu::detectors::Condition;
use dpulens::dpu::runbook;
use dpulens::engine::preset;
use dpulens::sim::{SimDur, SimTime, MS};

fn cfg() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    // Compute-dominated profile so a slow shard actually skews arrivals.
    cfg.engine.profile = preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.duration = SimDur::from_ms(1400);
    cfg.calib_windows = 300;
    cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 120.0 };
    cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    cfg
}

fn main() {
    println!("=== pathology demo: TP straggler (EW1) ===\n");
    let entry = runbook::entry(Condition::Ew1TpStraggler);
    println!("paper signal:     {}", entry.signal);
    println!("paper root cause: {}", entry.root_cause);
    println!("paper directive:  {}\n", entry.directive.paper_text());

    // Inject EW1 at t=700ms (after calibration).
    let mut c = cfg();
    c.inject = Some((Condition::Ew1TpStraggler, SimTime(700 * MS)));
    let res = Scenario::new(c).run();

    println!("injected: {}", res.injection_desc.clone().unwrap_or_default());
    let mut by_cond: std::collections::BTreeMap<&str, usize> = Default::default();
    for d in &res.detections {
        *by_cond.entry(d.condition.id()).or_insert(0) += 1;
    }
    println!("detections fired: {by_cond:?}");
    match res.detection_latency(Condition::Ew1TpStraggler) {
        Some(lat) => println!("EW1 detection latency: {lat}"),
        None => println!("EW1 NOT detected"),
    }
    if let Some(d) = res.detections.iter().find(|d| d.condition == Condition::Ew1TpStraggler) {
        println!("evidence: {} @ {} ({})", d.evidence, d.node, d.at);
    }

    println!("\nroot-cause attribution (4.2):");
    for a in res.attributions.iter().take(5) {
        println!("  {:?} ({:.0}%): {}", a.cause, a.confidence * 100.0, a.evidence);
    }

    // Closed loop: same fault, controller enabled.
    let mut c2 = cfg();
    c2.inject = Some((Condition::Ew1TpStraggler, SimTime(700 * MS)));
    c2.mitigate = true;
    let res2 = Scenario::new(c2).run();
    println!("\nclosed loop enabled:");
    for a in &res2.actions {
        println!("  [{}] {:?}: {}", a.at, a.directive, a.detail);
    }
    println!(
        "\nthroughput: faulted {:.0} tok/s -> closed-loop {:.0} tok/s",
        res.metrics.tok_per_s(),
        res2.metrics.tok_per_s()
    );
}
